//! The device-profile catalog: named, complete timing/energy/geometry
//! bundles ([`MemConfig`]) for the memory technologies the suite can put
//! in either controller slot, selected through the `dram.profile` /
//! `nvm.profile` knobs (DESIGN.md §8).
//!
//! A profile is authored at *paper scale* (`scale_factor = 1`);
//! [`DeviceProfile::mem_scaled`] applies exactly the per-device
//! transformations `Config::try_scaled` applies to the built-in pair, so
//! `dram.profile=ddr3-paper` + `nvm.profile=pcm-paper` reproduces the
//! baseline config bit-exactly at every scale (regression-tested in
//! `rust/tests/backend_profiles.rs`).
//!
//! Precedence contract: the profile knobs are declared FIRST in the knob
//! registry, so a profile expands into the whole `MemConfig` slot before
//! any explicit `dram.*`/`nvm.*` field override is applied — "profile
//! first, field overrides layered on top" holds regardless of the order
//! a spec/CLI set its knobs in.

use std::sync::OnceLock;

use super::{ns_to_cycles, Config, MemConfig, MemTech};

/// One named memory backend: a complete device bundle plus its
/// technology identity and a one-line description for `rainbow list`.
pub struct DeviceProfile {
    pub name: &'static str,
    pub tech: MemTech,
    pub summary: &'static str,
    mem: MemConfig,
}

impl DeviceProfile {
    /// The full-scale (Table IV-equivalent) device bundle.
    pub fn mem(&self) -> MemConfig {
        self.mem
    }

    /// The bundle scaled to `Config::scaled(factor)`'s capacity regime,
    /// mirroring its per-device transformations exactly: capacity and
    /// rows shrink by `factor` (rows clamped to ≥ 1), and the per-GB
    /// background draw scales back up so the background:dynamic energy
    /// balance survives the shrink (Fig. 12 depends on it).
    pub fn mem_scaled(&self, factor: u64) -> MemConfig {
        let mut m = self.mem;
        m.size /= factor;
        m.rows_per_bank = (m.rows_per_bank / factor).max(1);
        m.background_w_per_gb *= factor as f64;
        m
    }
}

/// Every registered profile, in catalog order.
pub fn all() -> &'static [DeviceProfile] {
    static CATALOG: OnceLock<Vec<DeviceProfile>> = OnceLock::new();
    CATALOG.get_or_init(build_catalog)
}

/// Look a profile up by name (case-insensitive).
pub fn by_name(name: &str) -> Option<&'static DeviceProfile> {
    all().iter().find(|p| p.name.eq_ignore_ascii_case(name))
}

/// Catalog names, for error messages and `rainbow list`.
pub fn names() -> Vec<&'static str> {
    all().iter().map(|p| p.name).collect()
}

/// The slow-tier (NVM-slot) profiles the `rainbow backends` matrix
/// sweeps by default: the design space the paper's claim must survive.
pub fn slow_tier_names() -> Vec<&'static str> {
    vec!["pcm-paper", "stt-ram", "optane-dcpmm", "cxl-remote"]
}

fn build_catalog() -> Vec<DeviceProfile> {
    let paper = Config::paper();
    let ghz = paper.cpu_ghz;
    vec![
        // The two Table IV devices, bit-exact with `Config::paper()` by
        // construction — the acceptance baseline for the profile API.
        DeviceProfile {
            name: "ddr3-paper",
            tech: MemTech::Dram,
            summary: "DDR3-1600 DRAM, Table IV (the baseline fast tier)",
            mem: paper.dram,
        },
        DeviceProfile {
            name: "pcm-paper",
            tech: MemTech::Pcm,
            summary: "PCM, Table IV (the baseline slow tier)",
            mem: paper.nvm,
        },
        // A fast, wide fast-tier alternative: many short rows across 8
        // channels, lower per-bit energy, slightly higher refresh draw.
        DeviceProfile {
            name: "hbm-like",
            tech: MemTech::Hbm,
            summary: "HBM-class stacked DRAM: 8 channels, 2 KB rows, fast",
            mem: MemConfig {
                tech: MemTech::Hbm,
                size: 4 << 30,
                channels: 8,
                ranks_per_channel: 1,
                banks_per_rank: 16,
                rows_per_bank: 16384,
                row_size: 32 * 64, // 2 KB rows (shorter than DDR3)
                read_cycles: ns_to_cycles(10.0, ghz),
                write_cycles: ns_to_cycles(18.0, ghz),
                t_cas: 7,
                t_rcd: 7,
                t_rp: 7,
                t_ras: 17,
                e_read_hit_pj_bit: 0.8,
                e_write_hit_pj_bit: 0.9,
                e_read_miss_pj_bit: 1.6,
                e_write_miss_pj_bit: 1.7,
                background_w_per_gb: 0.3,
            },
        },
        // Slow-tier alternatives spanning the NVM design space (Song et
        // al. asymmetries; Nomad's CXL-attached far tier).
        DeviceProfile {
            name: "stt-ram",
            tech: MemTech::SttRam,
            summary: "STT-MRAM: near-DRAM reads, ~1.6x writes, no standby",
            mem: MemConfig {
                tech: MemTech::SttRam,
                size: 32 << 30,
                channels: 4,
                ranks_per_channel: 8,
                banks_per_rank: 8,
                rows_per_bank: 65536,
                row_size: 32 * 64,
                read_cycles: ns_to_cycles(12.0, ghz),
                write_cycles: ns_to_cycles(45.0, ghz),
                t_cas: 9,
                t_rcd: 14,
                t_rp: 14,
                t_ras: 25,
                e_read_hit_pj_bit: 1.2,
                e_write_hit_pj_bit: 3.5,
                e_read_miss_pj_bit: 2.5,
                e_write_miss_pj_bit: 7.0,
                background_w_per_gb: 0.0,
            },
        },
        DeviceProfile {
            name: "optane-dcpmm",
            tech: MemTech::Optane,
            summary: "Optane-DCPMM-class: ~170 ns reads, 256 B lines, \
                      buffered writes",
            mem: MemConfig {
                tech: MemTech::Optane,
                size: 32 << 30,
                channels: 4,
                ranks_per_channel: 4,
                banks_per_rank: 16,
                rows_per_bank: 65536,
                row_size: 4 * 64, // 256 B internal access granularity
                read_cycles: ns_to_cycles(169.0, ghz),
                write_cycles: ns_to_cycles(94.0, ghz), // ADR write buffer
                t_cas: 9,
                t_rcd: 60,
                t_rp: 120,
                t_ras: 60,
                e_read_hit_pj_bit: 2.0,
                e_write_hit_pj_bit: 8.0,
                e_read_miss_pj_bit: 20.0,
                e_write_miss_pj_bit: 60.0,
                background_w_per_gb: 0.03, // ~4 W idle per 128 GB DIMM
            },
        },
        DeviceProfile {
            name: "cxl-remote",
            tech: MemTech::CxlDram,
            summary: "CXL-attached DRAM: DDR timing + ~170 ns link round \
                      trip, volatile",
            mem: MemConfig {
                tech: MemTech::CxlDram,
                size: 32 << 30,
                channels: 2,
                ranks_per_channel: 4,
                banks_per_rank: 8,
                rows_per_bank: 65536,
                row_size: 64 * 64,
                read_cycles: ns_to_cycles(13.5 + 170.0, ghz),
                write_cycles: ns_to_cycles(28.5 + 170.0, ghz),
                t_cas: 7,
                t_rcd: 7,
                t_rp: 7,
                t_ras: 18,
                e_read_hit_pj_bit: 2.1, // DRAM array + link SerDes
                e_write_hit_pj_bit: 2.2,
                e_read_miss_pj_bit: 3.2,
                e_write_miss_pj_bit: 3.3,
                background_w_per_gb: 0.225, // it is still DRAM
            },
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_resolves_and_names_are_unique() {
        let ps = all();
        assert!(ps.len() >= 6);
        for (i, p) in ps.iter().enumerate() {
            assert!(by_name(p.name).is_some());
            assert!(by_name(&p.name.to_uppercase()).is_some(),
                    "lookup must be case-insensitive");
            for other in &ps[i + 1..] {
                assert_ne!(p.name, other.name, "duplicate profile name");
            }
        }
        assert!(by_name("sdram-9000").is_none());
        for n in slow_tier_names() {
            assert!(by_name(n).is_some(), "stale slow-tier name {n}");
        }
    }

    #[test]
    fn paper_profiles_match_config_paper_bit_exactly() {
        let paper = Config::paper();
        assert_eq!(by_name("ddr3-paper").unwrap().mem(), paper.dram);
        assert_eq!(by_name("pcm-paper").unwrap().mem(), paper.nvm);
    }

    #[test]
    fn mem_scaled_mirrors_config_scaled() {
        for factor in [1u64, 8, 64] {
            let scaled = Config::scaled(factor);
            assert_eq!(by_name("ddr3-paper").unwrap().mem_scaled(factor),
                       scaled.dram, "dram at factor {factor}");
            assert_eq!(by_name("pcm-paper").unwrap().mem_scaled(factor),
                       scaled.nvm, "nvm at factor {factor}");
        }
    }

    #[test]
    fn background_power_scales_per_device_like_try_scaled() {
        // try_scaled compensates the per-GB background draw on BOTH
        // slots (a no-op for the 0 W/GB paper PCM); profiles with real
        // standby draw must follow the same rule, so a profile-built
        // slow tier and the scaled baseline keep one semantics.
        let cxl = by_name("cxl-remote").unwrap();
        assert_eq!(cxl.mem_scaled(8).background_w_per_gb,
                   cxl.mem().background_w_per_gb * 8.0);
        let scaled = Config::scaled(8);
        assert_eq!(scaled.dram.background_w_per_gb,
                   Config::paper().dram.background_w_per_gb * 8.0);
        assert_eq!(scaled.nvm.background_w_per_gb, 0.0);
    }

    #[test]
    fn every_profile_is_decode_safe_when_scaled() {
        for p in all() {
            let m = p.mem_scaled(64);
            assert!(m.channels > 0 && m.ranks_per_channel > 0
                        && m.banks_per_rank > 0, "{}", p.name);
            assert!(m.rows_per_bank >= 1, "{}", p.name);
            assert!(m.row_size >= 64, "{}", p.name);
            assert_eq!(m.tech, p.tech, "{}", p.name);
            // Extreme factors hit the rows clamp, never zero.
            assert!(p.mem_scaled(1 << 30).rows_per_bank >= 1);
        }
    }

    #[test]
    fn slow_tier_asymmetries_are_plausible() {
        let dram = by_name("ddr3-paper").unwrap().mem();
        for n in ["pcm-paper", "stt-ram", "optane-dcpmm", "cxl-remote"] {
            let m = by_name(n).unwrap().mem();
            assert!(m.read_cycles > dram.read_cycles, "{n} reads");
            assert!(m.write_cycles > dram.write_cycles, "{n} writes");
        }
        // Persistence identity drives the clflush reasoning.
        assert!(by_name("optane-dcpmm").unwrap().tech.is_nonvolatile());
        assert!(!by_name("cxl-remote").unwrap().tech.is_nonvolatile());
    }
}
