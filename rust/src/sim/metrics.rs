//! Run metrics: every counter needed to regenerate the paper's figures.
//!
//! One `RunMetrics` is produced per (workload, policy) simulation and the
//! report layer derives each figure from it: Fig. 7 MPKI, Fig. 8 TLB-miss
//! cycles, Fig. 9 translation breakdown, Fig. 10 IPC, Fig. 11 migration
//! traffic, Fig. 12 energy, Fig. 15 runtime-overhead breakdown.

/// Address-translation cycle breakdown (Fig. 9 categories).
#[derive(Clone, Debug, Default)]
pub struct XlatBreakdown {
    /// Split-TLB lookup cycles (hits and the lookup part of misses).
    pub tlb_cycles: u64,
    /// Bitmap-cache consultation cycles (hit latency + miss fill reads).
    pub bitmap_cycles: u64,
    /// 4 KB page-table walk cycles (flat systems).
    pub ptw_cycles: u64,
    /// Superpage table walk cycles (SPTW).
    pub sptw_cycles: u64,
    /// Address-remapping pointer reads (Rainbow DRAM addressing).
    pub remap_cycles: u64,
}

impl XlatBreakdown {
    pub fn total(&self) -> u64 {
        self.tlb_cycles + self.bitmap_cycles + self.ptw_cycles
            + self.sptw_cycles + self.remap_cycles
    }
}

/// Runtime (OS/mechanism) overhead breakdown (Fig. 15 categories).
#[derive(Clone, Debug, Default)]
pub struct RuntimeBreakdown {
    pub migration_cycles: u64,
    pub shootdown_cycles: u64,
    pub clflush_cycles: u64,
    /// Software hot-page identification (sorting/classification).
    pub identify_cycles: u64,
}

impl RuntimeBreakdown {
    pub fn total(&self) -> u64 {
        self.migration_cycles + self.shootdown_cycles + self.clflush_cycles
            + self.identify_cycles
    }
}

/// All statistics from one simulation run.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub instructions: u64,
    /// Wall cycles (max over cores) — the IPC denominator.
    pub cycles: u64,
    /// Total core-cycles (sum over cores) — the denominator for all
    /// "% of execution cycles" figures (8, 9, 15).
    pub core_cycles: u64,
    pub mem_ops: u64,

    // TLB behaviour.
    pub tlb_miss_4k: u64,
    pub tlb_miss_2m: u64,
    /// Cycles stalled on TLB miss handling (walks + remap reads).
    pub tlb_miss_cycles: u64,
    pub xlat: XlatBreakdown,
    /// Superpage TLB hit rate (R_hit of §III-E), sampled at end.
    pub sp_hit_rate: f64,

    // Bitmap cache (Rainbow only).
    pub bitmap_hits: u64,
    pub bitmap_misses: u64,
    /// Address-remap pointer reads performed.
    pub remap_reads: u64,

    // Migration activity.
    pub migrations: u64,
    pub migrated_bytes: u64,
    pub writebacks: u64,
    pub writeback_bytes: u64,
    pub shootdowns: u64,
    pub rt: RuntimeBreakdown,

    // Memory-system rollup (copied from devices at end of run).
    pub dram_reads: u64,
    pub dram_writes: u64,
    pub nvm_reads: u64,
    pub nvm_writes: u64,
    /// Row-buffer locality per tier (backend comparisons: Fig. 16 and
    /// `sweep --csv`).
    pub dram_row_hits: u64,
    pub dram_row_misses: u64,
    pub nvm_row_hits: u64,
    pub nvm_row_misses: u64,
    pub energy_pj: f64,
    /// Cycles cores spent stalled on memory (cache miss path).
    pub mem_stall_cycles: u64,
    pub llc_misses: u64,

    // Latency quantiles from the always-on telemetry histograms
    // (`telemetry::Hist` upper-bound-of-bucket convention: each value
    // is the power-of-two bucket bound holding the nearest rank).
    pub mig_lat_p50: u64,
    pub mig_lat_p95: u64,
    pub mig_lat_p99: u64,
    pub ptw_lat_p50: u64,
    pub ptw_lat_p95: u64,
    pub ptw_lat_p99: u64,
}

impl RunMetrics {
    /// Instructions per cycle across all cores (the paper's headline
    /// performance metric, Fig. 10).
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.instructions as f64 / self.cycles as f64
    }

    /// TLB misses per kilo-instruction (Fig. 7). Counts true misses of
    /// whichever page size(s) the policy uses.
    pub fn mpki(&self) -> f64 {
        if self.instructions == 0 {
            return 0.0;
        }
        (self.tlb_miss_4k + self.tlb_miss_2m) as f64
            / (self.instructions as f64 / 1000.0)
    }

    /// Denominator for per-cycle fractions: total core cycles when
    /// known, else wall cycles (single-core analyses).
    fn frac_denom(&self) -> f64 {
        if self.core_cycles > 0 {
            self.core_cycles as f64
        } else {
            self.cycles as f64
        }
    }

    /// Fraction of total cycles spent servicing TLB misses (Fig. 8).
    pub fn tlb_miss_cycle_frac(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.tlb_miss_cycles as f64 / self.frac_denom()
    }

    /// Fraction of cycles in address translation overall (Fig. 9 text).
    pub fn xlat_frac(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.xlat.total() as f64 / self.frac_denom()
    }

    /// Migration traffic as a fraction of the workload footprint
    /// (Fig. 11's y-axis). Footprint supplied by the caller.
    pub fn migration_traffic_ratio(&self, footprint_bytes: u64) -> f64 {
        if footprint_bytes == 0 {
            return 0.0;
        }
        (self.migrated_bytes + self.writeback_bytes) as f64
            / footprint_bytes as f64
    }

    /// Runtime overhead fraction (Fig. 15).
    pub fn runtime_overhead_frac(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.rt.total() as f64 / self.frac_denom()
    }

    pub fn bitmap_hit_rate(&self) -> f64 {
        let t = self.bitmap_hits + self.bitmap_misses;
        if t == 0 { 0.0 } else { self.bitmap_hits as f64 / t as f64 }
    }

    /// DRAM-tier row-buffer hit rate (0 when the tier saw no traffic).
    pub fn dram_row_hit_rate(&self) -> f64 {
        hit_rate(self.dram_row_hits, self.dram_row_misses)
    }

    /// NVM-tier row-buffer hit rate (0 when the tier saw no traffic).
    pub fn nvm_row_hit_rate(&self) -> f64 {
        hit_rate(self.nvm_row_hits, self.nvm_row_misses)
    }

    /// Energy in millijoules.
    pub fn energy_mj(&self) -> f64 {
        self.energy_pj / 1e9
    }
}

/// `hits / (hits + misses)`, 0 when there was no traffic — the one
/// rate convention shared by the per-run helpers above and the
/// cross-run aggregations in `report::figures` / the examples.
pub fn hit_rate(hits: u64, misses: u64) -> f64 {
    let t = hits + misses;
    if t == 0 { 0.0 } else { hits as f64 / t as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_metrics() {
        let m = RunMetrics {
            instructions: 2_000_000,
            cycles: 4_000_000,
            tlb_miss_4k: 1000,
            tlb_miss_2m: 500,
            tlb_miss_cycles: 400_000,
            migrated_bytes: 1 << 20,
            writeback_bytes: 1 << 20,
            energy_pj: 5e9,
            ..Default::default()
        };
        assert!((m.ipc() - 0.5).abs() < 1e-12);
        assert!((m.mpki() - 0.75).abs() < 1e-12);
        assert!((m.tlb_miss_cycle_frac() - 0.1).abs() < 1e-12);
        assert!((m.migration_traffic_ratio(4 << 20) - 0.5).abs() < 1e-12);
        assert!((m.energy_mj() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn zero_division_guards() {
        let m = RunMetrics::default();
        assert_eq!(m.ipc(), 0.0);
        assert_eq!(m.mpki(), 0.0);
        assert_eq!(m.tlb_miss_cycle_frac(), 0.0);
        assert_eq!(m.bitmap_hit_rate(), 0.0);
        assert_eq!(m.migration_traffic_ratio(0), 0.0);
        assert_eq!(m.dram_row_hit_rate(), 0.0);
        assert_eq!(m.nvm_row_hit_rate(), 0.0);
    }

    #[test]
    fn row_hit_rates_per_tier() {
        let m = RunMetrics {
            dram_row_hits: 3,
            dram_row_misses: 1,
            nvm_row_hits: 1,
            nvm_row_misses: 3,
            ..Default::default()
        };
        assert!((m.dram_row_hit_rate() - 0.75).abs() < 1e-12);
        assert!((m.nvm_row_hit_rate() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn breakdown_totals() {
        let x = XlatBreakdown {
            tlb_cycles: 1, bitmap_cycles: 2, ptw_cycles: 3,
            sptw_cycles: 4, remap_cycles: 5,
        };
        assert_eq!(x.total(), 15);
        let r = RuntimeBreakdown {
            migration_cycles: 1, shootdown_cycles: 2, clflush_cycles: 3,
            identify_cycles: 4,
        };
        assert_eq!(r.total(), 10);
    }
}
