//! The shared machine: per-core split TLBs, cache hierarchy, hybrid
//! memory, and page-table walker. Every policy embeds one and differs only
//! in how it translates addresses and moves pages.

use crate::cache::CacheHierarchy;
use crate::config::Config;
use crate::mem::HybridMemory;
use crate::telemetry::Telemetry;
use crate::tlb::{CoreTlbs, Walker, WalkerConfig};

use super::metrics::RunMetrics;

/// Where each policy keeps its page tables (timing-wise).
pub enum TableHome {
    Dram,
    Nvm,
}

pub struct Machine {
    pub cfg: Config,
    pub tlbs: Vec<CoreTlbs>,
    pub caches: CacheHierarchy,
    pub mem: HybridMemory,
    /// Walker for 4 KB-granularity page tables.
    pub walker: Walker,
    /// Walker for superpage tables (may target a different device).
    pub sp_walker: Walker,
    pub metrics: RunMetrics,
    /// Cycle-stamped telemetry sink. The latency histograms are always
    /// on (they feed the quantiles in [`RunMetrics`]); event/series
    /// rings record only after `tel.enable(..)` — see
    /// [`crate::telemetry`].
    pub tel: Telemetry,
}

impl Machine {
    /// `tables_4k` / `tables_2m`: which device holds each table tree
    /// (the paper's analytic model places flat 4 KB tables in DRAM and
    /// superpage tables with the data in NVM).
    pub fn new(cfg: &Config, tables_4k: TableHome, tables_2m: TableHome)
               -> Machine {
        let mem = HybridMemory::new(cfg);
        let table_len: u64 = 16 << 20;
        let home = |h: &TableHome| match h {
            // Park tables at the top of the device, away from data pages.
            TableHome::Dram => cfg.dram.size - table_len,
            TableHome::Nvm => mem.nvm_base() + cfg.nvm.size - table_len,
        };
        let walker = Walker::new(
            WalkerConfig { table_base: home(&tables_4k), table_len },
            cfg.ptw_levels_4k,
            cfg.ptw_levels_2m,
        );
        let sp_walker = Walker::new(
            WalkerConfig { table_base: home(&tables_2m), table_len },
            cfg.ptw_levels_4k,
            cfg.ptw_levels_2m,
        );
        Machine {
            cfg: cfg.clone(),
            tlbs: (0..cfg.cores).map(|_| CoreTlbs::new(cfg)).collect(),
            caches: CacheHierarchy::new(cfg),
            mem,
            walker,
            sp_walker,
            metrics: RunMetrics::default(),
            tel: Telemetry::default(),
        }
    }

    /// Memory-level parallelism factor: an OoO core overlaps ~4
    /// outstanding demand loads, so the pipeline stall per LLC-missing
    /// read is latency/MLP (translation, by contrast, serializes — walks
    /// are charged in full by the policies).
    pub const MLP: u64 = 4;
    /// Store-buffer drain factor: LLC-missing stores retire through a
    /// finite store buffer, so sustained slow-device writes (PCM: 547+
    /// cycles) back-pressure the core at latency/MLP_STORE.
    pub const MLP_STORE: u64 = 8;

    /// The data path below translation: caches, then memory on LLC miss,
    /// then any displaced dirty lines. Returns (stall cycles, llc_miss).
    pub fn data_path(&mut self, core: usize, paddr: u64, is_write: bool,
                     now: u64) -> (u64, bool) {
        let out = self.caches.access(core, paddr, is_write);
        let mut cycles = out.cycles;
        if out.llc_miss {
            let r = self.mem.access(now + cycles, paddr, is_write, 64);
            let stall = if is_write {
                r.latency / Self::MLP_STORE
            } else {
                r.latency / Self::MLP
            };
            cycles += stall;
            self.metrics.mem_stall_cycles += stall;
        }
        // Dirty victims stream out in the background; they occupy the
        // devices (affecting later accesses) but don't stall this load.
        for wb in out.writebacks.as_slice() {
            self.mem.access(now + cycles, wb.addr, true, 64);
        }
        (cycles, out.llc_miss)
    }

    /// Roll device/cache stats into the metrics snapshot (end of run).
    pub fn finalize(&mut self, elapsed_cycles: u64) {
        let m = &mut self.metrics;
        m.cycles = elapsed_cycles;
        m.dram_reads = self.mem.dram.stats.reads;
        m.dram_writes = self.mem.dram.stats.writes;
        m.nvm_reads = self.mem.nvm.stats.reads;
        m.nvm_writes = self.mem.nvm.stats.writes;
        m.dram_row_hits = self.mem.dram.stats.row_hits;
        m.dram_row_misses = self.mem.dram.stats.row_misses;
        m.nvm_row_hits = self.mem.nvm.stats.row_hits;
        m.nvm_row_misses = self.mem.nvm.stats.row_misses;
        m.energy_pj = self.mem.total_energy_pj(elapsed_cycles);
        m.llc_misses = self.caches.llc_misses();
        m.tlb_miss_4k = self.tlbs.iter().map(|t| t.misses_4k()).sum();
        m.tlb_miss_2m = self.tlbs.iter().map(|t| t.misses_2m()).sum();
        let rates: Vec<f64> =
            self.tlbs.iter().map(|t| t.sp_hit_rate()).collect();
        m.sp_hit_rate =
            rates.iter().sum::<f64>() / rates.len().max(1) as f64;
        m.mig_lat_p50 = self.tel.mig_hist.quantile(50);
        m.mig_lat_p95 = self.tel.mig_hist.quantile(95);
        m.mig_lat_p99 = self.tel.mig_hist.quantile(99);
        m.ptw_lat_p50 = self.tel.ptw_hist.quantile(50);
        m.ptw_lat_p95 = self.tel.ptw_hist.quantile(95);
        m.ptw_lat_p99 = self.tel.ptw_hist.quantile(99);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn data_path_hits_after_fill() {
        let mut cfg = Config::scaled(8);
        cfg.cores = 2;
        let mut m = Machine::new(&cfg, TableHome::Dram, TableHome::Nvm);
        let (c1, miss1) = m.data_path(0, 0x5000, false, 0);
        assert!(miss1);
        let (c2, miss2) = m.data_path(0, 0x5000, false, c1);
        assert!(!miss2);
        assert!(c2 < c1);
    }

    #[test]
    fn finalize_populates_rollup() {
        let mut cfg = Config::scaled(8);
        cfg.cores = 1;
        let mut m = Machine::new(&cfg, TableHome::Dram, TableHome::Nvm);
        m.data_path(0, 0x100, true, 0);
        m.metrics.instructions = 100;
        m.finalize(1000);
        assert_eq!(m.metrics.cycles, 1000);
        assert!(m.metrics.dram_reads + m.metrics.dram_writes > 0);
        assert!(m.metrics.energy_pj > 0.0);
    }

    #[test]
    fn nvm_access_slower_through_data_path() {
        let cfg = Config::scaled(8);
        let mut m = Machine::new(&cfg, TableHome::Dram, TableHome::Nvm);
        let nvm_base = m.mem.nvm_base();
        let (cd, _) = m.data_path(0, 0x40, false, 0);
        let (cn, _) = m.data_path(0, nvm_base + 0x40, false, 0);
        assert!(cn > cd);
    }
}
