//! Simulation core: the shared machine, the engine loop, and run metrics.

pub mod engine;
pub mod machine;
pub mod metrics;

pub use engine::{run, EngineConfig, RunOutcome};
pub use machine::{Machine, TableHome};
pub use metrics::{RunMetrics, RuntimeBreakdown, XlatBreakdown};
