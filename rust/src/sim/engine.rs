//! The simulation engine: drives per-core instruction streams through a
//! policy, firing sampling-interval callbacks and aggregating metrics.
//!
//! Clock model (DESIGN.md §5, zsim-style "bound-weave"): each core owns a
//! local cycle counter advanced by instruction retirement (CPI = 1 for
//! non-memory work) plus memory-path latency; cores are interleaved in
//! fixed quanta so device-level contention is observed in rough global
//! order. OS work at interval boundaries (identification + migration) is
//! charged stop-the-world to every core.

use crate::policies::Policy;
use crate::sim::metrics::RunMetrics;
use crate::telemetry::CumStats;
use crate::workloads::{Op, Workload};

/// Engine configuration.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Total instructions to retire across all cores.
    pub instructions: u64,
    /// Sampling interval in cycles.
    pub interval_cycles: u64,
    /// Core interleave quantum (instructions per scheduling turn).
    pub quantum: u64,
}

impl EngineConfig {
    pub fn new(instructions: u64, interval_cycles: u64) -> EngineConfig {
        EngineConfig { instructions, interval_cycles, quantum: 2000 }
    }
}

/// Outcome of a full simulation run.
pub struct RunOutcome {
    pub metrics: RunMetrics,
    /// Policy name for reporting.
    pub policy: &'static str,
    pub workload: String,
}

/// Cumulative machine counters at an epoch boundary; the telemetry sink
/// differences consecutive snapshots into per-epoch deltas.
fn cum_stats(policy: &dyn Policy, retired: &[u64]) -> CumStats {
    let m = policy.machine();
    CumStats {
        instructions: retired.iter().sum(),
        tlb_misses: m.tlbs.iter()
            .map(|t| t.misses_4k() + t.misses_2m())
            .sum(),
        migrated_bytes: m.metrics.migrated_bytes,
        dram_row_hits: m.mem.dram.stats.row_hits,
        dram_row_misses: m.mem.dram.stats.row_misses,
        nvm_row_hits: m.mem.nvm.stats.row_hits,
        nvm_row_misses: m.mem.nvm.stats.row_misses,
    }
}

/// Run `workload` under `policy` for `cfg.instructions` instructions.
pub fn run(policy: &mut dyn Policy, workload: &mut Workload,
           cfg: &EngineConfig) -> RunOutcome {
    let cores = workload.cores();
    let per_core = cfg.instructions / cores as u64;
    let mut clock = vec![0u64; cores];
    let mut retired = vec![0u64; cores];
    let mut mem_ops = 0u64;
    let mut next_interval = cfg.interval_cycles;

    // Round-robin in quanta until every core retires its share.
    let mut live = cores;
    while live > 0 {
        live = 0;
        for core in 0..cores {
            if retired[core] >= per_core {
                continue;
            }
            live += 1;
            let target = (retired[core] + cfg.quantum).min(per_core);
            while retired[core] < target {
                match workload.next_op(core) {
                    Op::Think(n) => {
                        let n = (n as u64).min(per_core - retired[core]).max(1);
                        retired[core] += n;
                        clock[core] += n; // CPI = 1
                    }
                    Op::Mem { vaddr, is_write } => {
                        let c = policy.access(core, vaddr, is_write,
                                              clock[core]);
                        clock[core] += c + 1;
                        retired[core] += 1;
                        mem_ops += 1;
                    }
                }
            }
        }
        // Interval boundary: when the slowest live core passes it.
        let min_clock = (0..cores)
            .filter(|&c| retired[c] < per_core)
            .map(|c| clock[c])
            .min()
            .unwrap_or_else(|| *clock.iter().max().unwrap());
        while min_clock >= next_interval {
            // OS work starts once every core has passed the boundary; use
            // the max clock so device timestamps are not in its future
            // (otherwise bulk copies would charge cross-core clock skew
            // as migration latency).
            let os_start = *clock.iter().max().unwrap();
            let os_cycles = policy.on_interval(os_start);
            workload.advance_phase();
            // Epoch telemetry: one time-series sample per interval,
            // stamped with the deterministic simulated clock. The
            // cumulative snapshot lives here (not in the sink) so the
            // sink stays policy-agnostic.
            let util_bp =
                (policy.dram_utilization() * 10_000.0).round() as u64;
            let cum = cum_stats(policy, &retired);
            policy.machine_mut().tel.epoch_roll(os_start + os_cycles,
                                                os_cycles, cum, util_bp);
            // Stop-the-world: OS work extends every core's timeline.
            for c in clock.iter_mut() {
                *c += os_cycles;
            }
            next_interval += cfg.interval_cycles;
        }
    }

    let elapsed = *clock.iter().max().unwrap();
    policy.finalize(elapsed);
    let m = policy.machine_mut();
    m.metrics.instructions = retired.iter().sum();
    m.metrics.mem_ops = mem_ops;
    m.metrics.cycles = elapsed;
    m.metrics.core_cycles = clock.iter().sum();
    RunOutcome {
        metrics: m.metrics.clone(),
        policy: policy.name(),
        workload: workload.name.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::policies::{from_name, FlatStatic};
    use crate::workloads::{AppProfile, Workload};

    fn small_cfg() -> Config {
        let mut c = Config::scaled(8);
        c.cores = 2;
        c.interval_cycles = 200_000;
        c.top_n = 16;
        c
    }

    fn small_workload(cfg: &Config) -> Workload {
        let p = AppProfile::by_name("DICT").unwrap();
        Workload::single(&p, cfg.cores, 64, 7)
    }

    #[test]
    fn run_retires_requested_instructions() {
        let cfg = small_cfg();
        let mut w = small_workload(&cfg);
        let mut p = FlatStatic::new(&cfg);
        let out = run(&mut p, &mut w,
                      &EngineConfig::new(100_000, cfg.interval_cycles));
        assert_eq!(out.metrics.instructions, 100_000);
        assert!(out.metrics.cycles > 100_000, "memory must add cycles");
        assert!(out.metrics.mem_ops > 20_000); // ~34% memops
        assert!(out.metrics.ipc() > 0.003 && out.metrics.ipc() < 1.0,
                "ipc={}", out.metrics.ipc());
    }

    #[test]
    fn intervals_fire_for_migrating_policies() {
        let cfg = small_cfg();
        let mut w = small_workload(&cfg);
        let mut p = from_name("rainbow", &cfg, false).unwrap();
        let out = run(p.as_mut(), &mut w,
                      &EngineConfig::new(400_000, cfg.interval_cycles));
        // DICT is hot-heavy: Rainbow must have migrated something.
        assert!(out.metrics.migrations > 0,
                "no migrations over {} cycles", out.metrics.cycles);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = small_cfg();
        let mk = || {
            let mut w = small_workload(&cfg);
            let mut p = FlatStatic::new(&cfg);
            run(&mut p, &mut w,
                &EngineConfig::new(50_000, cfg.interval_cycles))
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.metrics.cycles, b.metrics.cycles);
        assert_eq!(a.metrics.mem_ops, b.metrics.mem_ops);
        assert!((a.metrics.energy_pj - b.metrics.energy_pj).abs() < 1e-6);
    }

    #[test]
    fn epoch_series_recorded_when_enabled() {
        let cfg = small_cfg();
        let mut w = small_workload(&cfg);
        let mut p = from_name("rainbow", &cfg, false).unwrap();
        p.machine_mut().tel.enable(4096, 1024);
        let out = run(p.as_mut(), &mut w,
                      &EngineConfig::new(400_000, cfg.interval_cycles));
        let tel = &p.machine().tel;
        assert!(tel.epochs() > 0, "intervals must have fired");
        let series: Vec<_> = tel.series().collect();
        assert_eq!(series.len() as u64, tel.epochs());
        // Samples are cycle-ordered and the deltas roll up to no more
        // than the run totals.
        for pair in series.windows(2) {
            assert!(pair[0].cycle <= pair[1].cycle);
            assert_eq!(pair[0].epoch + 1, pair[1].epoch);
        }
        let instr: u64 = series.iter().map(|s| s.instructions).sum();
        assert!(instr <= out.metrics.instructions);
        let mig: u64 = series.iter().map(|s| s.migrated_bytes).sum();
        assert!(mig <= out.metrics.migrated_bytes);
    }

    #[test]
    fn all_policies_complete_a_run() {
        let cfg = small_cfg();
        for name in crate::policies::all_names() {
            let mut w = small_workload(&cfg);
            let mut p = from_name(name, &cfg, false).unwrap();
            let out = run(p.as_mut(), &mut w,
                          &EngineConfig::new(60_000, cfg.interval_cycles));
            assert_eq!(out.metrics.instructions, 60_000, "policy {name}");
            assert!(out.metrics.cycles > 0);
        }
    }
}
