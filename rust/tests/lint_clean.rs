//! `rainbow lint` tier-1 gate: the committed tree must lint clean
//! (including marker staleness and the schemas.lock wire-format
//! check), every rule family must both FIRE on a violation fixture
//! and SUPPRESS under a justified allow marker, and mutating a
//! serialized struct without bumping its version constant must fail
//! the schema-lock rule. See DESIGN.md §11 and docs/MANUAL.md §lint.

use rainbow::analysis::schema::{self, Tracked};
use rainbow::analysis::{self, lint_tree, LintConfig, SourceTree, RULES};

fn render(ds: &[analysis::Diagnostic]) -> String {
    ds.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
}

/// Rule ids produced by linting one in-memory fixture file (default
/// config: no staleness, no schema lock).
fn lint_one(path: &str, src: &str) -> Vec<String> {
    lint_tree(&SourceTree::from_files(&[(path, src)]),
              &LintConfig::default())
        .iter()
        .map(|d| d.rule.to_string())
        .collect()
}

// ------------------------------------------------- the committed tree

#[test]
fn committed_tree_lints_clean() {
    let src = analysis::default_src_dir();
    let tree = SourceTree::from_dir(&src).unwrap();
    let lock = analysis::load_lock(&src).unwrap();
    assert!(lock.is_some(),
            "rust/schemas.lock must be committed next to rust/src");
    let ds = lint_tree(&tree, &LintConfig {
        stale_allows: true,
        schemas_lock: lock,
    });
    assert!(ds.is_empty(),
            "committed tree must lint clean, got {} finding(s):\n{}",
            ds.len(), render(&ds));
}

#[test]
fn committed_lock_restamps_byte_identically() {
    // `rainbow lint --update-schemas` on the committed tree must be a
    // no-op: the lock in git is exactly what the generator emits.
    let src = analysis::default_src_dir();
    let tree = SourceTree::from_dir(&src).unwrap();
    let lock = analysis::load_lock(&src).unwrap().unwrap();
    let fresh = schema::update_lock(&tree, Some(lock.as_str()),
                                    schema::TRACKED).unwrap();
    assert_eq!(fresh, lock,
               "rust/schemas.lock drifted from the generator output; \
                run `rainbow lint --update-schemas` and commit");
}

#[test]
fn mutating_a_serialized_struct_without_version_bump_fails() {
    // The acceptance criterion: grow RunSpec (a serde_kv-serialized
    // struct) in memory without touching SPEC_VERSION — the lock
    // check must flag it and --update-schemas must refuse to bless it.
    let src = analysis::default_src_dir();
    let mut tree = SourceTree::from_dir(&src).unwrap();
    let lock = analysis::load_lock(&src).unwrap();
    let anchor = "pub struct RunSpec {";
    let f = tree
        .files
        .iter_mut()
        .find(|f| f.path == "report/spec.rs")
        .expect("report/spec.rs in the tree");
    assert!(f.text.contains(anchor), "RunSpec anchor moved");
    f.text = f.text.replace(
        anchor, "pub struct RunSpec {\n    pub lint_canary: u64,");
    let ds = lint_tree(&tree, &LintConfig {
        stale_allows: false,
        schemas_lock: lock.clone(),
    });
    let hit = ds.iter().find(|d| {
        d.rule == "wire-schema" && d.file == "report/spec.rs"
    });
    let hit = hit.unwrap_or_else(|| {
        panic!("expected a wire-schema finding for report/spec.rs, \
                got:\n{}", render(&ds))
    });
    assert!(hit.msg.contains("bump the version constant"),
            "repair hint missing: {}", hit.msg);
    let e = schema::update_lock(&tree, lock.as_deref(), schema::TRACKED)
        .unwrap_err();
    assert!(e.contains("refused"), "got: {e}");

    // Bumping SPEC_VERSION alongside turns the finding into a plain
    // "lock is stale, re-stamp" — and --update-schemas now agrees.
    let v = tree
        .files
        .iter_mut()
        .find(|f| f.path == "report/serde_kv.rs")
        .unwrap();
    let vanchor = "pub const SPEC_VERSION: u64 = 1;";
    assert!(v.text.contains(vanchor), "SPEC_VERSION anchor moved");
    v.text =
        v.text.replace(vanchor, "pub const SPEC_VERSION: u64 = 2;");
    let ds = lint_tree(&tree, &LintConfig {
        stale_allows: false,
        schemas_lock: lock.clone(),
    });
    assert!(ds.iter().all(|d| d.rule == "wire-schema"), "{}",
            render(&ds));
    assert!(ds.iter().any(|d| d.msg.contains("--update-schemas")),
            "re-stamp hint missing:\n{}", render(&ds));
    let lock2 = schema::update_lock(&tree, lock.as_deref(),
                                    schema::TRACKED).unwrap();
    let ds = lint_tree(&tree, &LintConfig {
        stale_allows: false,
        schemas_lock: Some(lock2),
    });
    assert!(ds.is_empty(), "{}", render(&ds));
}

// --------------------------------------------- hot-path rule family

#[test]
fn hot_collections_fires_and_suppresses() {
    let bad = "use std::collections::HashMap;\n\
               pub struct T { m: HashMap<u64, u64> }\n";
    assert_eq!(lint_one("tlb/lookup.rs", bad),
               ["hot-collections", "hot-collections"]);
    // The same text in a cold module is fine.
    assert!(lint_one("report/figures.rs", bad).is_empty());
    // A justified marker on the preceding line suppresses.
    let ok = "// rainbow-lint: allow(hot-collections, fixture: model \
              table)\nuse std::collections::HashMap;\n";
    assert!(lint_one("tlb/lookup.rs", ok).is_empty());
    // Test code is exempt wholesale.
    let tests = "#[cfg(test)]\nmod tests {\n    \
                 use std::collections::HashMap;\n}\n";
    assert!(lint_one("tlb/lookup.rs", tests).is_empty());
}

#[test]
fn hot_alloc_fires_and_exempts_constructors() {
    let bad = "impl T {\n    pub fn access(&mut self) {\n        \
               self.buf = Vec::new();\n        \
               let s = format!(\"x\");\n    }\n}\n";
    assert_eq!(lint_one("rainbow/remap.rs", bad),
               ["hot-alloc", "hot-alloc"]);
    // Constructor-shaped functions may allocate: that is their job.
    let ctor = "impl T {\n    pub fn new() -> T {\n        \
                T { buf: Vec::new() }\n    }\n    \
                pub fn from_parts() -> T {\n        \
                T { buf: vec![1] }\n    }\n}\n";
    assert!(lint_one("rainbow/remap.rs", ctor).is_empty());
    // A justified marker suppresses a genuine exception.
    let marked = "pub fn access() {\n    \
                  // rainbow-lint: allow(hot-alloc, fixture: \
                  amortized)\n    let v = Vec::new();\n}\n";
    assert!(lint_one("cache/cache.rs", marked).is_empty());
}

// ------------------------------------------- determinism rule family

#[test]
fn nondet_clock_fires_outside_the_harness() {
    let bad = "fn stamp() {\n    let t0 = Instant::now();\n}\n";
    assert_eq!(lint_one("sim/engine.rs", bad), ["nondet-clock"]);
    // The measurement harness itself is the exemption.
    assert!(lint_one("perf.rs", bad).is_empty());
    assert!(lint_one("util/bench.rs", bad).is_empty());
    let marked = "fn stamp() {\n    \
                  // rainbow-lint: allow(nondet-clock, fixture: \
                  operator display)\n    let t0 = Instant::now();\n}\n";
    assert!(lint_one("sim/engine.rs", marked).is_empty());
}

#[test]
fn nondet_iter_fires_inside_to_kv_functions() {
    // Unordered iteration feeding the wire format — even when the
    // HashMap only appears in the signature, it belongs to the fn.
    let bad = "fn widget_to_kv(m: &HashMap<u64, u64>) -> String {\n    \
               String::new()\n}\n";
    assert_eq!(lint_one("report/serde_extra.rs", bad), ["nondet-iter"]);
    // The same type in a non-serialization fn of a cold module is fine.
    let ok = "fn build(m: &HashMap<u64, u64>) {}\n";
    assert!(lint_one("report/serde_extra.rs", ok).is_empty());
    let marked = "// rainbow-lint: allow(nondet-iter, fixture: sorted \
                  before emit)\nfn widget_to_kv(m: &HashMap<u64, u64>) \
                  -> String {\n    String::new()\n}\n";
    assert!(lint_one("report/serde_extra.rs", marked).is_empty());
}

// ----------------------------------------- panic-hygiene rule family

#[test]
fn panic_protocol_fires_in_protocol_files_only() {
    let bad = "fn read_frame(s: &mut S) -> u64 {\n    \
               s.next().unwrap();\n    s.len().expect(\"len\");\n    \
               panic!(\"nope\")\n}\n";
    assert_eq!(lint_one("report/netstore.rs", bad),
               ["panic-protocol", "panic-protocol", "panic-protocol"]);
    // Same code outside the protocol files is not this rule's business.
    assert!(lint_one("report/figures.rs", bad).is_empty());
    // Test code in a protocol file may unwrap freely.
    let tests = "#[cfg(test)]\nmod tests {\n    #[test]\n    \
                 fn t() { x().unwrap(); }\n}\n";
    assert!(lint_one("report/store.rs", tests).is_empty());
    let marked = "fn f() {\n    \
                  // rainbow-lint: allow(panic-protocol, fixture: \
                  infallible by construction)\n    x().unwrap();\n}\n";
    assert!(lint_one("report/shard.rs", marked).is_empty());
}

#[test]
fn unsafe_audit_requires_safety_comments() {
    let bad = "fn f() {\n    unsafe { core(); }\n}\n";
    assert_eq!(lint_one("util/x.rs", bad), ["unsafe-audit"]);
    let ok = "fn f() {\n    // SAFETY: fixture — bounds checked \
              above\n    unsafe { core(); }\n}\n";
    assert!(lint_one("util/x.rs", ok).is_empty());
}

// ------------------------------------------------- marker hygiene

#[test]
fn allow_hygiene_rejects_malformed_markers() {
    for (src, why) in [
        ("// rainbow-lint: allow(hot-alloc)\n", "missing reason"),
        ("// rainbow-lint: allow(hot-alloc, )\n", "empty reason"),
        ("// rainbow-lint: allow(bogus-rule, because)\n",
         "unknown rule id"),
        ("// rainbow-lint: allow(wire-schema, because)\n",
         "unsuppressible rule"),
        ("// rainbow-lint: disable-everything\n", "malformed marker"),
    ] {
        let got = lint_one("util/x.rs", src);
        assert_eq!(got, ["allow-hygiene"], "{why}: got {got:?}");
    }
}

#[test]
fn stale_allow_flags_markers_that_suppress_nothing() {
    let src = "// rainbow-lint: allow(hot-alloc, fixture: nothing \
               here)\nfn f() {}\n";
    let tree = SourceTree::from_files(&[("util/x.rs", src)]);
    // Off by default: a stale marker is only noise, not a failure.
    assert!(lint_tree(&tree, &LintConfig::default()).is_empty());
    let ds = lint_tree(&tree, &LintConfig {
        stale_allows: true,
        schemas_lock: None,
    });
    assert_eq!(ds.len(), 1, "{}", render(&ds));
    assert_eq!((ds[0].rule, ds[0].line), ("stale-allow", 1));
}

// ------------------------------------------------- wire-format lock

const WIRE_TRACKED: &[Tracked] = &[Tracked {
    struct_file: "wire.rs",
    struct_name: "Rec",
    version_file: "wire.rs",
    version_const: "VERSION",
}];

#[test]
fn schema_lock_version_bump_workflow() {
    let v1 = SourceTree::from_files(&[(
        "wire.rs",
        "pub const VERSION: u64 = 1;\n\
         pub struct Rec { pub a: u64 }\n",
    )]);
    let lock = schema::render_lock(&v1, WIRE_TRACKED).unwrap();
    assert!(schema::check(&v1, Some(lock.as_str()), WIRE_TRACKED)
        .is_empty());
    // Missing lock is itself a finding, not a silent pass.
    let ds = schema::check(&v1, None, WIRE_TRACKED);
    assert_eq!(ds.len(), 1);
    assert_eq!(ds[0].rule, "wire-schema");

    // Layout drifts, version does not: flagged, and re-stamp refused.
    let drift = SourceTree::from_files(&[(
        "wire.rs",
        "pub const VERSION: u64 = 1;\n\
         pub struct Rec { pub a: u64, pub b: u32 }\n",
    )]);
    let ds = schema::check(&drift, Some(lock.as_str()), WIRE_TRACKED);
    assert_eq!(ds.len(), 1, "{}", render(&ds));
    assert_eq!(ds[0].rule, "wire-schema");
    assert!(ds[0].msg.contains("bump the version constant"),
            "{}", ds[0].msg);
    let e = schema::update_lock(&drift, Some(lock.as_str()),
                                WIRE_TRACKED)
        .unwrap_err();
    assert!(e.contains("refused"), "got: {e}");

    // Version bumped alongside: stale lock, re-stamp allowed, clean.
    let bumped = SourceTree::from_files(&[(
        "wire.rs",
        "pub const VERSION: u64 = 2;\n\
         pub struct Rec { pub a: u64, pub b: u32 }\n",
    )]);
    let ds = schema::check(&bumped, Some(lock.as_str()), WIRE_TRACKED);
    assert_eq!(ds.len(), 1, "{}", render(&ds));
    assert!(ds[0].msg.contains("--update-schemas"), "{}", ds[0].msg);
    let lock2 = schema::update_lock(&bumped, Some(lock.as_str()),
                                    WIRE_TRACKED).unwrap();
    assert!(schema::check(&bumped, Some(lock2.as_str()), WIRE_TRACKED)
        .is_empty());

    // Comment / attribute / formatting churn never touches the lock.
    let cosmetic = SourceTree::from_files(&[(
        "wire.rs",
        "pub const VERSION: u64 = 1;\n/// doc\n#[derive(Clone)]\n\
         pub struct Rec {\n    // why a exists\n    pub a: u64,\n}\n",
    )]);
    assert!(schema::check(&cosmetic, Some(lock.as_str()), WIRE_TRACKED)
        .is_empty());
}

// ------------------------------------------------------- CLI surface

fn rainbow_bin() -> std::process::Command {
    let mut c = std::process::Command::new(env!("CARGO_BIN_EXE_rainbow"));
    c.current_dir(env!("CARGO_MANIFEST_DIR"));
    c
}

#[test]
fn cli_lint_exits_zero_on_the_committed_tree() {
    let out = rainbow_bin()
        .args(["lint", "--stale-allows"])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(),
            "lint failed\nstdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("lint clean"), "got: {stdout}");
}

#[test]
fn cli_lint_list_rules_names_every_rule() {
    let out = rainbow_bin()
        .args(["lint", "--list-rules"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    for r in RULES {
        assert!(stdout.contains(r.id),
                "--list-rules must name {}", r.id);
    }
}

#[test]
fn cli_lint_exits_nonzero_on_findings() {
    let dir = std::env::temp_dir()
        .join(format!("rainbow_lint_cli_{}", std::process::id()));
    std::fs::create_dir_all(dir.join("tlb")).unwrap();
    std::fs::write(dir.join("tlb/x.rs"),
                   "fn access() {\n    let v = Vec::new();\n}\n")
        .unwrap();
    let out = rainbow_bin()
        .args(["lint", "--src", dir.to_str().unwrap()])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1),
               "findings must exit 1, got {:?}", out.status.code());
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stdout.contains("hot-alloc"), "got: {stdout}");
    assert!(stderr.contains("lint finding"), "got: {stderr}");
    std::fs::remove_dir_all(&dir).unwrap();
}
