//! Conformance contract for every `CacheStore` implementation: one
//! parameterized suite (get/put round-trip, missing key, list,
//! concurrent puts of the same fingerprint, liveness) run against
//! `FsStore`, `MemStore`, `NetStore` — the latter talking to a
//! real `CacheServer` on an ephemeral port in this process —
//! `LogStore` (the `--log` durable form, restarted between put and
//! get), and `ReplStore` (three in-process servers behind one
//! consistent-hash handle, including read-repair and degraded
//! operation with dead replicas) — plus per-store corrupt-entry
//! rejection (a clean error naming the entry, never a panic, never
//! silently different metrics) and the server's input hardening.

use std::thread;

use rainbow::report::netstore::CacheServer;
use rainbow::report::replica::{Ring, REPLICATION};
use rainbow::report::serde_kv::metrics_to_kv;
use rainbow::report::Store;
use rainbow::sim::RunMetrics;

fn sample_metrics(seed: u64) -> RunMetrics {
    RunMetrics {
        instructions: 1_000 + seed,
        cycles: 5_000 + seed * 3,
        mem_ops: 400 + seed,
        migrations: seed,
        energy_pj: 123.5 + seed as f64,
        sp_hit_rate: 0.5,
        ..RunMetrics::default()
    }
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("rainbow_store_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The parameterized suite every store must pass.
fn conformance(store: &Store, label: &str) {
    // Missing key: a miss, not an error.
    assert!(store.get("v2_missing_x_s8_i1_r0").unwrap().is_none(),
            "{label}: missing key must read as None");
    // Put/get round-trip is byte-identical through the kv encoding.
    let m = sample_metrics(7);
    store.put("fp_a", &m).unwrap();
    let got = store.get("fp_a").unwrap().expect("fp_a stored");
    assert_eq!(metrics_to_kv(&m), metrics_to_kv(&got),
               "{label}: round-trip must preserve every field");
    // Overwriting with the same bytes is legal (determinism makes all
    // writers of one fingerprint agree).
    store.put("fp_a", &m).unwrap();
    // List returns every fingerprint, sorted.
    store.put("fp_b", &sample_metrics(9)).unwrap();
    let listed = store.list().unwrap();
    assert!(listed.contains(&"fp_a".to_string())
                && listed.contains(&"fp_b".to_string()),
            "{label}: list must cover stored entries, got {listed:?}");
    assert!(listed.windows(2).all(|w| w[0] <= w[1]),
            "{label}: list must be sorted, got {listed:?}");
    // Liveness probe.
    store.ping().unwrap_or_else(|e| panic!("{label}: ping: {e}"));
    // Concurrent puts of the SAME fingerprint must all succeed and
    // leave an intact entry (atomic rename / mutexed map / server-side
    // serialization — whichever, no torn result).
    let m2 = sample_metrics(11);
    thread::scope(|scope| {
        for _ in 0..8 {
            scope.spawn(|| store.put("fp_conc", &m2).unwrap());
        }
    });
    let got = store.get("fp_conc").unwrap().expect("fp_conc stored");
    assert_eq!(metrics_to_kv(&m2), metrics_to_kv(&got),
               "{label}: concurrent puts must leave an intact entry");
}

#[test]
fn fs_store_conformance_and_corruption() {
    let dir = tmp_dir("fs");
    let store = Store::fs(dir.clone());
    conformance(&store, "FsStore");
    // Corrupt-entry rejection: tamper a stored value behind the
    // store's back — the checksum catches it as a clean error.
    let path = dir.join("fp_a.kv");
    let good = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, good.replace("cycles=", "cycles=9")).unwrap();
    let e = store.get("fp_a").unwrap_err();
    assert!(e.contains("corrupt") && e.contains("checksum"), "got: {e}");
    // A stale-version entry (older build) is a miss, not corruption —
    // re-simulation heals it transparently.
    std::fs::write(&path, "version=1\nchecksum=0\n").unwrap();
    assert!(store.get("fp_a").unwrap().is_none());
    // Garbage that never was a metrics entry is corrupt.
    std::fs::write(&path, "not a kv file\n").unwrap();
    assert!(store.get("fp_a").is_err());
    // In-flight temp files never show up in list().
    std::fs::write(dir.join("fp_z.kv.tmp.1.0"), "partial").unwrap();
    assert!(!store
        .list()
        .unwrap()
        .iter()
        .any(|fp| fp.contains("tmp")));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mem_store_conformance() {
    conformance(&Store::mem(), "MemStore");
}

#[test]
fn net_store_conformance_against_in_process_server() {
    // Server fronting an in-memory store on an ephemeral port: the
    // full shared-nothing client path, no filesystem involved.
    let server = CacheServer::bind("127.0.0.1:0", Store::mem()).unwrap();
    let hostport = server.local_addr().to_string();
    let handle = server.spawn();
    let store = Store::net(&hostport);
    conformance(&store, "NetStore");
    // Clean shutdown: acknowledged, accept loop drained, thread joined.
    handle.stop().expect("clean cache-server shutdown");
    // A stopped server is a clean client error, not a hang or panic.
    let e = store.ping().unwrap_err();
    assert!(e.contains(&hostport), "error must name the server: {e}");
}

/// The durable form of the suite: a log-backed store passes the full
/// contract, and — the satellite's restart clause — a store reopened
/// on the same log serves every previously-acked entry byte-identical,
/// with compaction (what a clean `--stop` runs) collapsing the append
/// history to one record per live entry.
#[test]
fn log_store_conformance_and_durability_across_restart() {
    let dir = tmp_dir("wal");
    std::fs::create_dir_all(&dir).unwrap();
    let log = dir.join("cache.log");
    {
        let (store, stats) = Store::logged(&log).unwrap();
        assert_eq!(stats.loaded, 0, "fresh log must replay empty");
        conformance(&store, "LogStore");
    }
    // "Restart": the store above is dropped (the crash boundary the
    // in-process form can express) and reopened on the same log file.
    let (store, stats) = Store::logged(&log).unwrap();
    assert!(stats.loaded >= 3,
            "replay must apply every logged append, got {stats:?}");
    assert_eq!(stats.truncated_bytes, 0);
    assert_eq!(store.list().unwrap(), vec!["fp_a", "fp_b", "fp_conc"]);
    for (fp, seed) in [("fp_a", 7), ("fp_b", 9), ("fp_conc", 11)] {
        let got = store.get(fp).unwrap().expect(fp);
        assert_eq!(metrics_to_kv(&sample_metrics(seed)),
                   metrics_to_kv(&got),
                   "{fp}: restart must preserve the entry byte-for-byte");
    }
    // Compaction drops overwritten duplicates; a reopen replays
    // exactly one record per live entry.
    store.compact().unwrap();
    drop(store);
    let (store, stats) = Store::logged(&log).unwrap();
    assert_eq!(stats.loaded, 3, "compacted log: one record per entry");
    assert_eq!(store.list().unwrap(), vec!["fp_a", "fp_b", "fp_conc"]);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The replicated form of the suite: three in-process servers behind
/// one `tcp://a,tcp://b,tcp://c` handle pass the full contract; a read
/// served by a fallback replica repairs the primary; and a dead
/// replica degrades every operation to a warning — not a failure —
/// until the last replica dies.
#[test]
fn repl_store_conformance_read_repair_and_degraded_operation() {
    let mut hostports: Vec<String> = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..3 {
        let server =
            CacheServer::bind("127.0.0.1:0", Store::mem()).unwrap();
        hostports.push(server.local_addr().to_string());
        handles.push(Some(server.spawn()));
    }
    let addr = hostports
        .iter()
        .map(|hp| format!("tcp://{hp}"))
        .collect::<Vec<_>>()
        .join(",");
    let store = Store::parse(&addr).unwrap();
    conformance(&store, "ReplStore");

    // Read-repair: plant an entry directly on the FALLBACK replica
    // only (bypassing the handle — the state a crashed-and-restarted
    // primary would be in), then read through the handle: the fallback
    // answers, and the primary is repaired with the entry.
    let ring = Ring::new(&hostports);
    let placed = ring.replicas("fp_repair", REPLICATION);
    assert_eq!(placed.len(), 2);
    let m = sample_metrics(21);
    Store::net(&hostports[placed[1]]).put("fp_repair", &m).unwrap();
    let primary = Store::net(&hostports[placed[0]]);
    assert!(primary.get("fp_repair").unwrap().is_none(),
            "precondition: the primary must start without the entry");
    let got = store.get("fp_repair").unwrap().expect("fallback hit");
    assert_eq!(metrics_to_kv(&m), metrics_to_kv(&got));
    let healed = primary
        .get("fp_repair")
        .unwrap()
        .expect("read-repair must populate the primary");
    assert_eq!(metrics_to_kv(&m), metrics_to_kv(&healed));

    // Degraded operation: stop the replica that is primary for
    // fp_repair, then drive a fingerprint placed on it — put, get,
    // list, and ping must all still succeed off the surviving partner.
    let dead = placed[0];
    handles[dead].take().unwrap().stop().unwrap();
    let on_dead = (0..)
        .map(|i| format!("fp_deg_{i}"))
        .find(|fp| ring.replicas(fp, REPLICATION).contains(&dead))
        .unwrap();
    let m2 = sample_metrics(22);
    store.put(&on_dead, &m2)
        .expect("put must degrade, not fail, with one replica dead");
    let got = store.get(&on_dead).unwrap().expect("degraded get");
    assert_eq!(metrics_to_kv(&m2), metrics_to_kv(&got));
    assert!(store.list().unwrap().contains(&on_dead));
    store.ping().expect("ping must succeed while any replica lives");

    // Only when EVERY replica is gone do operations error.
    for h in handles.iter_mut() {
        if let Some(h) = h.take() {
            h.stop().unwrap();
        }
    }
    assert!(store.ping().is_err(),
            "ping must fail once every replica is dead");
    assert!(store.put("fp_doomed", &m2).is_err(),
            "put must fail once every placed replica is dead");
}

#[test]
fn net_store_surfaces_corruption_and_rejects_path_fingerprints() {
    let dir = tmp_dir("net_fs");
    let server =
        CacheServer::bind("127.0.0.1:0", Store::fs(dir.clone())).unwrap();
    let hostport = server.local_addr().to_string();
    let handle = server.spawn();
    let store = Store::net(&hostport);
    store.put("fp_x", &sample_metrics(3)).unwrap();
    // Corrupt the entry on disk behind the server: GET must surface
    // the server-side integrity error verbatim, with the server named.
    let path = dir.join("fp_x.kv");
    let good = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, good.replace("cycles=", "cycles=9")).unwrap();
    let e = store.get("fp_x").unwrap_err();
    assert!(e.contains("corrupt") && e.contains(&hostport), "got: {e}");
    // Path-shaped fingerprints cannot address files outside the store
    // directory — rejected server-side before touching the fs.
    assert!(store.get("../evil").is_err());
    assert!(store.put("a/b", &sample_metrics(1)).is_err());
    handle.stop().expect("clean cache-server shutdown");
    let _ = std::fs::remove_dir_all(&dir);
}
