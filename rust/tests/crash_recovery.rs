//! Crash-recovery contract of the durable cache server: a real child
//! `rainbow cache-server --mem --log` process is populated over TCP,
//! SIGKILLed with no warning, and restarted on the same log file —
//! every entry that was acknowledged before the kill must be served
//! byte-identical afterwards, a torn tail appended by the "crash" must
//! be truncated loudly (never parsed into metrics), re-running the
//! same matrix must repopulate only fingerprints that are actually
//! missing, and a clean `--stop` must compact the log to one record
//! per live entry.

use std::fs::{self, OpenOptions};
use std::io::Write;
use std::path::Path;
use std::process::{Child, Command, Stdio};

use rainbow::report::serde_kv::metrics_to_kv;
use rainbow::report::{run_stored, run_uncached, RunSpec, Store};

fn tiny(workload: &str, policy: &str, seed: u64) -> RunSpec {
    RunSpec::new(workload, policy)
        .with_scale(64)
        .with_instructions(40_000)
        .with_seed(seed)
}

/// Six distinct cells — enough appends that the kill lands on a log
/// with real history, small enough to stay fast.
fn specs() -> Vec<RunSpec> {
    let mut out = Vec::new();
    for p in ["flat", "rainbow", "hscc4k"] {
        for seed in [41, 42] {
            out.push(tiny("DICT", p, seed));
        }
    }
    out
}

/// Spawn `cache-server --mem --log` on an ephemeral port and wait for
/// its port file; optionally capture stdout (the replay banner).
fn spawn_server(log: &Path, port_file: &Path, stdout_to: Option<&Path>)
                -> (Child, String) {
    let _ = fs::remove_file(port_file);
    let stdout = match stdout_to {
        Some(p) => {
            Stdio::from(fs::File::create(p).expect("stdout capture file"))
        }
        None => Stdio::null(),
    };
    let child = Command::new(env!("CARGO_BIN_EXE_rainbow"))
        .arg("cache-server")
        .arg("--mem")
        .arg("--log").arg(log)
        .arg("--listen").arg("127.0.0.1:0")
        .arg("--port-file").arg(port_file)
        .stdout(stdout)
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn cache-server");
    let mut hostport = String::new();
    for _ in 0..400 {
        if let Ok(s) = fs::read_to_string(port_file) {
            if !s.trim().is_empty() {
                hostport = s.trim().to_string();
                break;
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(25));
    }
    assert!(!hostport.is_empty(),
            "cache-server never wrote its port file");
    (child, hostport)
}

#[test]
fn sigkilled_log_server_restarts_with_every_acked_entry() {
    let dir = std::env::temp_dir().join(format!(
        "rainbow_crash_e2e_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).expect("mkdir");
    let log = dir.join("cache.log");
    let port_file = dir.join("port.txt");
    let specs = specs();

    // Phase 1: populate through a live server. run_stored returning Ok
    // IS the acknowledgement — and the log contract fsyncs every
    // record before the server acks, so each of these entries is on
    // stable storage by the time the loop advances.
    let (mut child, hostport) =
        spawn_server(&log, &port_file, None);
    let store = Store::net(&hostport);
    for s in &specs {
        run_stored(&store, s).expect("populate");
    }
    assert_eq!(store.list().expect("list").len(), specs.len());

    // SIGKILL: no goodbye, no compaction, no flush beyond what each
    // acked PUT already forced.
    child.kill().expect("SIGKILL cache-server");
    child.wait().expect("reap cache-server");
    let clean_len = fs::metadata(&log).expect("log exists").len();

    // Stack the other crash signature on top: a record header whose
    // declared payload never made it to disk (kill mid-append).
    let mut f = OpenOptions::new().append(true).open(&log).unwrap();
    f.write_all(b"put=fp_torn len=4096 checksum=0123456789abcdef\nshort")
        .unwrap();
    drop(f);

    // Phase 2: restart on the same log.
    let banner_path = dir.join("restart.stdout");
    let (mut child, hostport) =
        spawn_server(&log, &port_file, Some(&banner_path));
    let store = Store::net(&hostport);

    // Every acked entry survived, byte-identical to a serial replay.
    for s in &specs {
        let m = store
            .get(&s.fingerprint())
            .expect("get after restart")
            .expect("acked entry must survive SIGKILL + restart");
        assert_eq!(metrics_to_kv(&run_uncached(s)), metrics_to_kv(&m),
                   "{} x {} (seed {}) diverged across the crash",
                   s.workload, s.policy, s.seed);
    }
    // The torn tail was truncated — loudly (the replay banner says how
    // many bytes) — never served as an entry.
    assert!(store.get("fp_torn").expect("get").is_none(),
            "a torn record must not become an entry");
    assert_eq!(fs::metadata(&log).unwrap().len(), clean_len,
               "restart must truncate the log back to its clean prefix");
    let banner = fs::read_to_string(&banner_path).unwrap();
    assert!(banner.contains(
                &format!("replayed {} record(s)", specs.len())),
            "replay banner must count the records: {banner}");
    assert!(banner.contains("torn byte(s) truncated"),
            "replay banner must admit the truncation: {banner}");

    // Re-running the matrix plus one genuinely new cell repopulates
    // ONLY the missing fingerprint: cached cells are served, not
    // re-put, so each old fingerprint still has exactly one record.
    let mut more = specs.clone();
    more.push(tiny("streamcluster", "rainbow", 7));
    for s in &more {
        run_stored(&store, s).expect("re-run after restart");
    }
    assert_eq!(store.list().expect("list").len(), more.len());
    let log_text = fs::read_to_string(&log).unwrap();
    for s in &specs {
        let header = format!("put={} ", s.fingerprint());
        assert_eq!(log_text.matches(&header).count(), 1,
                   "{}: a cache hit must not append a duplicate record",
                   s.fingerprint());
    }

    // Clean `--stop` compacts: a reopen replays exactly one record per
    // live entry, with nothing torn.
    let status = Command::new(env!("CARGO_BIN_EXE_rainbow"))
        .arg("cache-server")
        .arg("--stop").arg(format!("tcp://{hostport}"))
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .status()
        .expect("run cache-server --stop");
    assert!(status.success(), "--stop must succeed");
    let status = child.wait().expect("wait server after --stop");
    assert!(status.success(), "server must exit 0 after --stop");
    let (reopened, stats) =
        Store::logged(&log).expect("reopen compacted log");
    assert_eq!(stats.loaded, more.len(),
               "compaction must leave one record per live entry");
    assert_eq!(stats.truncated_bytes, 0);
    assert_eq!(reopened.list().expect("list").len(), more.len());
    let _ = fs::remove_dir_all(&dir);
}
