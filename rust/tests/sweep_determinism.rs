//! Determinism contract of the sweep orchestrators: a fixed-seed
//! workload x policy matrix — including override-bearing specs — executed
//! on scoped worker threads must yield metrics BYTE-identical (via the kv
//! serialization) to the serial `run_uncached` path, and repeated
//! parallel runs must agree with each other — any cross-worker state
//! sharing or ordering race would surface as drift between rounds.
//! The same contract holds across the PROCESS boundary: a sharded sweep
//! executed by real child `rainbow shard-worker` processes and merged
//! from the shared cache must match the serial replay byte-for-byte —
//! and across WORKER DEATH: a dynamically-dispatched (job-queue) sweep
//! must survive a SIGKILLed `queue-worker` mid-run, re-lease its jobs,
//! and still match the serial replay byte-for-byte.

use rainbow::report::netstore::{CacheServer, NetStore};
use rainbow::report::serde_kv::{metrics_to_kv, spec_from_kv, spec_to_kv};
use rainbow::report::shard::{self, ShardConfig};
use rainbow::report::sweep::{self, SweepConfig};
use rainbow::report::{run_cached_in, run_uncached, RunSpec, Store};

fn tiny(workload: &str, policy: &str) -> RunSpec {
    RunSpec::new(workload, policy)
        .with_scale(64)
        .with_instructions(60_000)
        .with_seed(42)
        .with("rainbow.interval_cycles", 100_000u64)
        .with("rainbow.top_n", 16u64)
}

/// Workload x policy cross product plus override-bearing variants: the
/// §IV-F-style config knobs (migration threshold, NVM latency) that only
/// overrides can express must ride the same parallel path.
fn matrix() -> Vec<RunSpec> {
    let mut specs = Vec::new();
    for w in ["DICT", "streamcluster"] {
        for p in ["flat", "rainbow", "hscc4k"] {
            specs.push(tiny(w, p));
        }
    }
    specs.push(tiny("DICT", "rainbow")
        .with("rainbow.migration_threshold", 250.0));
    specs.push(tiny("DICT", "flat").with("nvm.read_cycles", 248u64));
    specs
}

#[test]
fn parallel_sweep_matches_serial_byte_identical_twice() {
    let specs = matrix();
    let serial: Vec<String> =
        specs.iter().map(|s| metrics_to_kv(&run_uncached(s))).collect();
    // Two rounds: catches both serial/parallel divergence and
    // run-to-run ordering races in the worker pool.
    for round in 0..2 {
        let cfg = SweepConfig { workers: 4, ..SweepConfig::default() };
        let parallel = sweep::run_parallel(&specs, &cfg);
        assert_eq!(parallel.len(), specs.len());
        for ((spec, want), got) in
            specs.iter().zip(&serial).zip(&parallel)
        {
            assert_eq!(*want, metrics_to_kv(got),
                       "round {round}: {} x {} diverged from serial",
                       spec.workload, spec.policy);
        }
    }
}

#[test]
fn duplicate_specs_share_one_simulation() {
    let mut specs = matrix();
    specs.extend(matrix()); // every fingerprint appears twice
    let cfg = SweepConfig { workers: 3, ..SweepConfig::default() };
    let out = sweep::run(&specs, &cfg);
    assert_eq!(out.unique_runs, specs.len() / 2,
               "dedup must collapse repeated fingerprints");
    let half = specs.len() / 2;
    for i in 0..half {
        assert_eq!(metrics_to_kv(&out.metrics[i]),
                   metrics_to_kv(&out.metrics[i + half]),
                   "duplicate {i} must reuse the cached result");
    }
}

#[test]
fn single_worker_equals_many_workers() {
    let specs = matrix();
    let one = sweep::run_parallel(
        &specs, &SweepConfig { workers: 1, ..SweepConfig::default() });
    let many = sweep::run_parallel(
        &specs, &SweepConfig { workers: 8, ..SweepConfig::default() });
    for (i, (a, b)) in one.iter().zip(&many).enumerate() {
        assert_eq!(metrics_to_kv(a), metrics_to_kv(b),
                   "spec {i}: worker count changed the metrics");
    }
}

/// The tentpole contract: a 2-shard sweep executed by REAL child
/// `rainbow shard-worker` processes (the compiled binary, not an
/// in-process shortcut) and merged from the fingerprint-named cache
/// entries must be byte-identical to a serial `run_uncached` replay —
/// specs survive the spec-list file round-trip, the cache survives the
/// process boundary, and duplicates still collapse to one simulation.
#[test]
fn sharded_sweep_crosses_process_boundary_byte_identical() {
    let dir = std::env::temp_dir().join(format!(
        "rainbow_shard_e2e_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut specs = matrix();
    specs.push(specs[0].clone()); // duplicate shares one simulation
    let unique = matrix().len();
    let cfg = ShardConfig {
        parallel: 2,
        cmd: Some(vec![env!("CARGO_BIN_EXE_rainbow").to_string(),
                       "shard-worker".to_string()]),
        ..ShardConfig::new(2, dir.clone())
    };
    let out = shard::run_sharded(&specs, &cfg).expect("sharded sweep");
    assert_eq!(out.shards_run, 2);
    assert_eq!(out.unique_runs, unique);
    assert_eq!(out.metrics.len(), specs.len());
    for (s, m) in specs.iter().zip(&out.metrics) {
        assert_eq!(metrics_to_kv(&run_uncached(s)), metrics_to_kv(m),
                   "{} x {} diverged across the process boundary",
                   s.workload, s.policy);
    }
    // The duplicate was served from the same cache entry.
    assert_eq!(metrics_to_kv(&out.metrics[0]),
               metrics_to_kv(out.metrics.last().unwrap()));
    // The coordinator left an auditable layout behind: a versioned
    // manifest plus one strict-parsing spec list per shard.
    let work = dir.join("shards");
    let man = shard::manifest_from_kv(
        &std::fs::read_to_string(work.join("manifest.kv")).unwrap())
        .unwrap();
    assert_eq!(man.total_specs, specs.len());
    assert_eq!(man.unique_specs, unique);
    let mut listed = 0;
    for (file, n) in &man.shard_files {
        let text = std::fs::read_to_string(work.join(file)).unwrap();
        let part = rainbow::report::serde_kv::specs_from_kv(&text).unwrap();
        assert_eq!(part.len(), *n);
        listed += part.len();
    }
    assert_eq!(listed, unique);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The shared-nothing form of the same contract: coordinator and REAL
/// child `rainbow shard-worker` processes share NOTHING but a TCP
/// connection to an in-memory `cache-server` — no cache directory
/// exists anywhere — and the merged metrics must still be
/// byte-identical to a serial `run_uncached` replay (what `sweep
/// --shards N --store tcp://... --check` asserts in CI).
#[test]
fn sharded_sweep_through_cache_server_no_shared_fs() {
    let dir = std::env::temp_dir().join(format!(
        "rainbow_netshard_e2e_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let server = CacheServer::bind("127.0.0.1:0", Store::mem())
        .expect("bind ephemeral port");
    let hostport = server.local_addr().to_string();
    let handle = server.spawn();
    let mut specs = matrix();
    specs.push(specs[1].clone()); // duplicate shares one entry
    let unique = matrix().len();
    let cfg = ShardConfig {
        parallel: 2,
        cmd: Some(vec![env!("CARGO_BIN_EXE_rainbow").to_string(),
                       "shard-worker".to_string()]),
        ..ShardConfig::with_store(2, Store::net(&hostport),
                                  dir.join("shards"))
    };
    let out = shard::run_sharded(&specs, &cfg).expect("net-sharded sweep");
    assert_eq!(out.shards_run, 2);
    assert_eq!(out.unique_runs, unique);
    assert_eq!(out.metrics.len(), specs.len());
    for (s, m) in specs.iter().zip(&out.metrics) {
        assert_eq!(metrics_to_kv(&run_uncached(s)), metrics_to_kv(m),
                   "{} x {} diverged through the cache server",
                   s.workload, s.policy);
    }
    assert_eq!(metrics_to_kv(&out.metrics[1]),
               metrics_to_kv(out.metrics.last().unwrap()),
               "the duplicate must be served from the same entry");
    // Every result lives in the server's memory, nowhere on disk: the
    // workers were handed only `--store tcp://...`.
    let held = Store::net(&hostport).list().expect("list");
    assert_eq!(held.len(), unique);
    handle.stop().expect("clean cache-server shutdown");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The work-stealing form of the shared-nothing contract, THROUGH a
/// worker death: the matrix is enqueued on an in-memory cache server's
/// job queue, real child `rainbow queue-worker` processes lease one
/// spec at a time, and one of them is SIGKILLed mid-run. Any lease the
/// victim died holding must expire (500 ms deadline here) and be
/// re-granted to the survivors, duplicate COMPLETEs from stragglers
/// must stay idempotent, and the merged metrics must still be
/// byte-identical to a serial `run_uncached` replay — zero shared
/// filesystem, zero lost or double-counted cells.
#[test]
fn queued_sweep_survives_worker_death_byte_identical() {
    let server = CacheServer::bind("127.0.0.1:0", Store::mem())
        .expect("bind ephemeral port")
        .with_lease_ms(500);
    let hostport = server.local_addr().to_string();
    let handle = server.spawn();

    let specs = matrix();
    let client = NetStore::new(&hostport);
    let stat = client.enqueue_jobs(&specs).expect("enqueue");
    assert_eq!(stat.total as usize, specs.len());
    assert_eq!(stat.pending as usize, specs.len());

    let spawn_worker = |id: &str| {
        std::process::Command::new(env!("CARGO_BIN_EXE_rainbow"))
            .arg("queue-worker")
            .arg("--store").arg(format!("tcp://{hostport}"))
            .arg("--worker-id").arg(id)
            .stdout(std::process::Stdio::null())
            .stderr(std::process::Stdio::null())
            .spawn()
            .expect("spawn queue-worker")
    };

    // The victim starts alone; once it has at least one COMPLETE in,
    // kill it cold (SIGKILL — no goodbye, no REQUEUE: whatever lease
    // it held simply times out server-side).
    let mut victim = spawn_worker("victim");
    let mut seen_completed = 0;
    for _ in 0..2000 {
        let s = client.queue_stat().expect("qstat");
        seen_completed = s.completed;
        if s.completed >= 1 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    assert!(seen_completed >= 1, "victim never completed a job");
    victim.kill().expect("kill victim");
    victim.wait().expect("reap victim");

    // Two survivors drain the rest — including any job the victim died
    // holding, which rejoins the pending set once its deadline passes.
    // A queue-worker only exits 0 when the server reports the queue
    // drained, so a clean join here IS the drain barrier.
    let mut survivors = vec![spawn_worker("survivor-1"),
                             spawn_worker("survivor-2")];
    for w in &mut survivors {
        let status = w.wait().expect("wait survivor");
        assert!(status.success(), "survivor exited non-zero");
    }
    let stat = client.queue_stat().expect("qstat after drain");
    assert!(stat.drained(), "queue not drained: {stat:?}");
    assert_eq!(stat.completed as usize, specs.len(),
               "every cell must be completed exactly once");

    // The merged result set — served purely from the server's memory,
    // no cache directory anywhere — is byte-identical to serial replay.
    let store = Store::net(&hostport);
    let metrics = sweep::collect_stored(&store, &specs).expect("collect");
    for (s, m) in specs.iter().zip(&metrics) {
        assert_eq!(metrics_to_kv(&run_uncached(s)), metrics_to_kv(m),
                   "{} x {} diverged through the job queue",
                   s.workload, s.policy);
    }
    handle.stop().expect("clean cache-server shutdown");
}

/// The replicated form of the work-stealing contract, THROUGH a
/// REPLICA death: the matrix is enqueued on the first endpoint of a
/// 3-server `--store tcp://a,tcp://b,tcp://c` set, real child
/// `rainbow queue-worker` processes execute it against the replicated
/// store, and one replica is SIGKILLed mid-sweep. Consistent-hash
/// placement keeps every fingerprint on 2 replicas and a dead replica
/// degrades reads/writes to warnings, so the workers must finish
/// cleanly and the merged metrics must still be byte-identical to a
/// serial `run_uncached` replay.
#[test]
fn queued_sweep_survives_replica_death_byte_identical() {
    // Scheduler (first endpoint) and one survivor run in-process; the
    // victim is a real child `cache-server --mem` process so it can be
    // SIGKILLed with no chance to flush or say goodbye.
    let server_a = CacheServer::bind("127.0.0.1:0", Store::mem())
        .expect("bind scheduler");
    let a = server_a.local_addr().to_string();
    let handle_a = server_a.spawn();
    let server_b = CacheServer::bind("127.0.0.1:0", Store::mem())
        .expect("bind survivor");
    let b = server_b.local_addr().to_string();
    let handle_b = server_b.spawn();

    let dir = std::env::temp_dir().join(format!(
        "rainbow_repl_e2e_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("mkdir");
    let port_file = dir.join("victim.port");
    let mut victim =
        std::process::Command::new(env!("CARGO_BIN_EXE_rainbow"))
            .arg("cache-server")
            .arg("--mem")
            .arg("--listen").arg("127.0.0.1:0")
            .arg("--port-file").arg(&port_file)
            .stdout(std::process::Stdio::null())
            .stderr(std::process::Stdio::null())
            .spawn()
            .expect("spawn victim cache-server");
    let mut c = String::new();
    for _ in 0..400 {
        if let Ok(s) = std::fs::read_to_string(&port_file) {
            if !s.trim().is_empty() {
                c = s.trim().to_string();
                break;
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(25));
    }
    assert!(!c.is_empty(), "victim cache-server never wrote its port");

    let store_arg = format!("tcp://{a},tcp://{b},tcp://{c}");
    let specs = matrix();
    let client = NetStore::new(&a);
    let stat = client.enqueue_jobs(&specs).expect("enqueue");
    assert_eq!(stat.pending as usize, specs.len());

    let spawn_worker = |id: &str| {
        std::process::Command::new(env!("CARGO_BIN_EXE_rainbow"))
            .arg("queue-worker")
            .arg("--store").arg(&store_arg)
            .arg("--worker-id").arg(id)
            .stdout(std::process::Stdio::null())
            .stderr(std::process::Stdio::null())
            .spawn()
            .expect("spawn queue-worker")
    };
    let mut workers =
        vec![spawn_worker("repl-w1"), spawn_worker("repl-w2")];

    // SIGKILL the victim once the sweep is demonstrably under way —
    // entries already replicated, more still being written.
    let mut seen_completed = 0;
    for _ in 0..2000 {
        let s = client.queue_stat().expect("qstat");
        seen_completed = s.completed;
        if seen_completed >= 2 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    assert!(seen_completed >= 2, "workers never completed early cells");
    victim.kill().expect("SIGKILL victim replica");
    victim.wait().expect("reap victim replica");

    // The workers must drain the queue anyway — a dead replica is a
    // warning on their side, never a failed cell.
    for w in &mut workers {
        let status = w.wait().expect("wait queue-worker");
        assert!(status.success(),
                "a worker failed after the replica death");
    }
    let stat = client.queue_stat().expect("qstat after drain");
    assert!(stat.drained(), "queue not drained: {stat:?}");

    // Byte-identity through the degraded store: collect_stored never
    // simulates, so every cell must be served from a surviving replica
    // — each fingerprint lives on 2 of 3 endpoints, and write-through
    // put every acked entry on at least one endpoint that is still up.
    let store = Store::parse(&store_arg).expect("parse replicated store");
    let metrics = sweep::collect_stored(&store, &specs).expect("collect");
    for (s, m) in specs.iter().zip(&metrics) {
        assert_eq!(metrics_to_kv(&run_uncached(s)), metrics_to_kv(m),
                   "{} x {} diverged through the replicated store",
                   s.workload, s.policy);
    }
    handle_a.stop().expect("clean scheduler shutdown");
    handle_b.stop().expect("clean survivor shutdown");
    let _ = std::fs::remove_dir_all(&dir);
}

/// An unreachable cache server must fail a sharded sweep fast — one
/// clean coordinator-side error before any child spawns, not N
/// identical worker failures (or a hang).
#[test]
fn sharded_sweep_fails_fast_when_server_unreachable() {
    let dir = std::env::temp_dir().join(format!(
        "rainbow_netshard_down_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let specs = vec![
        RunSpec::new("DICT", "flat").with_scale(64).with_instructions(20_000),
    ];
    // Reserve a port and close it so nothing is listening there.
    let dead = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let cfg = ShardConfig {
        cmd: Some(vec![env!("CARGO_BIN_EXE_rainbow").to_string(),
                       "shard-worker".to_string()]),
        ..ShardConfig::with_store(2, Store::net(&dead), dir.join("shards"))
    };
    let e = shard::run_sharded(&specs, &cfg).unwrap_err();
    assert!(e.contains("store unavailable"), "got: {e}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A failing shard worker (non-zero exit) must fail the whole sharded
/// sweep with the shard named, not produce a silently partial result
/// set — and a worker handed a corrupt spec-list file must be such a
/// failure (it exits non-zero before simulating anything).
#[test]
fn sharded_sweep_reports_failed_workers() {
    let dir = std::env::temp_dir().join(format!(
        "rainbow_shard_fail_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let specs = vec![
        RunSpec::new("DICT", "flat").with_scale(64).with_instructions(20_000),
        RunSpec::new("DICT", "rainbow")
            .with_scale(64)
            .with_instructions(20_000),
    ];
    // Workers that exit non-zero without touching the cache.
    let cfg = ShardConfig {
        cmd: Some(vec!["sh".to_string(), "-c".to_string(),
                       "exit 3".to_string()]),
        ..ShardConfig::new(2, dir.clone())
    };
    let e = shard::run_sharded(&specs, &cfg).unwrap_err();
    assert!(e.contains("shard workers failed"), "got: {e}");
    // An unspawnable worker command errors out immediately.
    let cfg = ShardConfig {
        cmd: Some(vec!["/no/such/rainbow-worker".to_string()]),
        ..ShardConfig::new(2, dir.clone())
    };
    let e = shard::run_sharded(&specs, &cfg).unwrap_err();
    assert!(e.contains("spawn"), "got: {e}");
    // And the real worker binary handed a corrupt (truncated) spec
    // list exits non-zero before simulating anything.
    let corrupt = dir.join("corrupt.kv");
    let full = rainbow::report::serde_kv::specs_to_kv(&specs);
    std::fs::write(&corrupt, &full[..full.len() - 25]).unwrap();
    let cache = dir.join("worker-cache");
    let status = std::process::Command::new(env!("CARGO_BIN_EXE_rainbow"))
        .arg("shard-worker")
        .arg("--specs").arg(&corrupt)
        .arg("--cache-dir").arg(&cache)
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .status()
        .expect("spawn shard-worker");
    assert!(!status.success(),
            "a corrupt spec list must fail the worker process");
    assert!(!cache.exists(), "the failed worker must not simulate");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The telemetry contract (DESIGN.md §14): tracing observes without
/// perturbing. Two traced runs of the same spec must render
/// byte-identical `--trace-out` files, and the traced run's metrics
/// must equal an untraced run's bit-for-bit (via the kv
/// serialization) — the sink never feeds back into timing.
#[test]
fn traced_runs_render_byte_identical_and_do_not_perturb_metrics() {
    use rainbow::report::{run_traced, trace_meta};
    use rainbow::telemetry::trace::{read_trace, render_trace};
    let spec = tiny("DICT", "rainbow");
    let meta = trace_meta(&spec);
    let (m1, t1) = run_traced(&spec);
    let (m2, t2) = run_traced(&spec);
    let a = render_trace(&meta, &m1, &t1);
    let b = render_trace(&meta, &m2, &t2);
    assert_eq!(a, b, "repeated traced runs must render byte-identical");
    assert_eq!(metrics_to_kv(&m1), metrics_to_kv(&m2));
    assert_eq!(metrics_to_kv(&m1), metrics_to_kv(&run_uncached(&spec)),
               "tracing must not perturb the simulated outcome");
    // The emitted file passes its own strict reader (the trace-smoke
    // validation), carries the run's identity, and its records are
    // internally consistent: epochs held + dropped account for every
    // roll, and events arrive cycle-ordered.
    let s = read_trace(&a).expect("emitted trace must parse strictly");
    assert_eq!(s.meta.fingerprint, spec.fingerprint());
    assert_eq!(s.epochs.len() as u64 + t1.series_dropped(), t1.epochs());
    assert!(s.events.windows(2).all(|w| w[0].cycle <= w[1].cycle),
            "events must be cycle-ordered");
}

#[test]
fn overrides_change_identity_and_outcome() {
    // The override-bearing spec must not collide with its base spec in
    // the cache/dedup layer, and the knob must actually reach the
    // simulation: flat serves everything from NVM, so quadrupling the
    // NVM read latency must slow it down.
    let base = tiny("DICT", "flat");
    let slow = base.clone().with("nvm.read_cycles",
                                 base.config().nvm.read_cycles * 4);
    assert_ne!(base.fingerprint(), slow.fingerprint());
    let m_base = run_uncached(&base);
    let m_slow = run_uncached(&slow);
    assert!(m_slow.cycles > m_base.cycles,
            "4x NVM read latency must cost cycles ({} vs {})",
            m_slow.cycles, m_base.cycles);
}

#[test]
fn override_spec_cache_roundtrip_identical() {
    let dir = std::env::temp_dir().join(format!(
        "rainbow_ov_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let spec = tiny("DICT", "rainbow")
        .with("rainbow.migration_threshold", 250.0)
        .with("nvm.write_cycles", 1000u64);
    let fresh = run_cached_in(&dir, &spec); // simulates + writes
    let cached = run_cached_in(&dir, &spec); // must load the entry
    assert_eq!(metrics_to_kv(&fresh), metrics_to_kv(&cached),
               "cache round-trip must be byte-identical");
    assert!(dir.join(format!("{}.kv", spec.fingerprint())).is_file());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn override_fingerprint_stable_under_insertion_order_and_spec_kv() {
    let a = tiny("DICT", "rainbow")
        .with("rainbow.migration_threshold", 250.0)
        .with("nvm.read_cycles", 124u64);
    let b = tiny("DICT", "rainbow")
        .with("nvm.read_cycles", 124u64)
        .with("rainbow.migration_threshold", 250.0);
    assert_eq!(a.fingerprint(), b.fingerprint());
    // And the canonical spec serialization round-trips the identity.
    let c = spec_from_kv(&spec_to_kv(&a)).unwrap();
    assert_eq!(a, c);
    assert_eq!(a.fingerprint(), c.fingerprint());
}
