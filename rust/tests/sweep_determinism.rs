//! Determinism contract of the parallel sweep orchestrator: a fixed-seed
//! workload x policy matrix executed on scoped worker threads must yield
//! metrics BYTE-identical (via the kv serialization) to the serial
//! `run_uncached` path, and repeated parallel runs must agree with each
//! other — any cross-worker state sharing or ordering race would surface
//! as drift between rounds.

use rainbow::report::serde_kv::metrics_to_kv;
use rainbow::report::sweep::{self, SweepConfig};
use rainbow::report::{run_uncached, RunSpec};

fn tiny(workload: &str, policy: &str) -> RunSpec {
    let mut s = RunSpec::new(workload, policy);
    s.scale = 64;
    s.instructions = 60_000;
    s.interval_cycles = 100_000;
    s.top_n = 16;
    s.seed = 42;
    s
}

fn matrix() -> Vec<RunSpec> {
    let mut specs = Vec::new();
    for w in ["DICT", "streamcluster"] {
        for p in ["flat", "rainbow", "hscc4k"] {
            specs.push(tiny(w, p));
        }
    }
    specs
}

#[test]
fn parallel_sweep_matches_serial_byte_identical_twice() {
    let specs = matrix();
    let serial: Vec<String> =
        specs.iter().map(|s| metrics_to_kv(&run_uncached(s))).collect();
    // Two rounds: catches both serial/parallel divergence and
    // run-to-run ordering races in the worker pool.
    for round in 0..2 {
        let parallel = sweep::run_parallel(
            &specs, &SweepConfig { workers: 4, disk_cache: false });
        assert_eq!(parallel.len(), specs.len());
        for ((spec, want), got) in
            specs.iter().zip(&serial).zip(&parallel)
        {
            assert_eq!(*want, metrics_to_kv(got),
                       "round {round}: {} x {} diverged from serial",
                       spec.workload, spec.policy);
        }
    }
}

#[test]
fn duplicate_specs_share_one_simulation() {
    let mut specs = matrix();
    specs.extend(matrix()); // every fingerprint appears twice
    let out =
        sweep::run(&specs, &SweepConfig { workers: 3, disk_cache: false });
    assert_eq!(out.unique_runs, specs.len() / 2,
               "dedup must collapse repeated fingerprints");
    let half = specs.len() / 2;
    for i in 0..half {
        assert_eq!(metrics_to_kv(&out.metrics[i]),
                   metrics_to_kv(&out.metrics[i + half]),
                   "duplicate {i} must reuse the cached result");
    }
}

#[test]
fn single_worker_equals_many_workers() {
    let specs = matrix();
    let one = sweep::run_parallel(
        &specs, &SweepConfig { workers: 1, disk_cache: false });
    let many = sweep::run_parallel(
        &specs, &SweepConfig { workers: 8, disk_cache: false });
    for (i, (a, b)) in one.iter().zip(&many).enumerate() {
        assert_eq!(metrics_to_kv(a), metrics_to_kv(b),
                   "spec {i}: worker count changed the metrics");
    }
}
