//! CLI argument-parsing contract for the `sweep`/`run` spec surface:
//! invalid `--set` keys/values, malformed `--spec` files, and bad
//! policy/workload names must all be rejected with a clear error BEFORE
//! any worker thread spawns (`report::spec_cli` is the library half of
//! `main.rs`'s argument handling).

use rainbow::config::knobs::KnobValue;
use rainbow::report::serde_kv::{spec_from_kv, spec_to_kv};
use rainbow::report::{spec_cli, RunSpec};
use rainbow::util::cli::Args;

fn parse(raw: &[&str]) -> Args {
    let raw: Vec<String> = raw.iter().map(|s| s.to_string()).collect();
    Args::parse(&raw, &[]).unwrap()
}

#[test]
fn defaults_and_options_build_a_spec() {
    let s = spec_cli::spec_from_args(&parse(&["run"])).unwrap();
    assert_eq!((s.workload.as_str(), s.policy.as_str()), ("mcf", "rainbow"));
    assert_eq!((s.scale, s.instructions), (8, 4_000_000));
    let s = spec_cli::spec_from_args(&parse(&[
        "run", "--app", "GUPS", "--policy", "flat", "--scale", "16",
        "--instructions", "5000", "--seed", "9", "--interval", "200000",
        "--top-n", "32",
    ]))
    .unwrap();
    assert_eq!((s.workload.as_str(), s.policy.as_str()), ("GUPS", "flat"));
    assert_eq!((s.scale, s.instructions, s.seed), (16, 5000, 9));
    assert_eq!(s.overrides.get("rainbow.interval_cycles"),
               Some(KnobValue::U64(200_000)));
    assert_eq!(s.overrides.get("rainbow.top_n"), Some(KnobValue::U64(32)));
}

#[test]
fn set_overrides_are_validated_before_any_fanout() {
    // Good sets stack.
    let s = spec_cli::spec_from_args(&parse(&[
        "sweep", "--set", "rainbow.migration_threshold=4000",
        "--set", "nvm.read_cycles=124",
    ]))
    .unwrap();
    assert_eq!(s.overrides.get("rainbow.migration_threshold"),
               Some(KnobValue::F64(4000.0)));
    assert_eq!(s.overrides.get("nvm.read_cycles"),
               Some(KnobValue::U64(124)));
    // Unknown knob key.
    let e = spec_cli::spec_from_args(&parse(&[
        "sweep", "--set", "rainbow.bogus_knob=1",
    ]))
    .unwrap_err();
    assert!(e.contains("unknown config knob"), "got: {e}");
    // Ill-typed value.
    let e = spec_cli::spec_from_args(&parse(&[
        "sweep", "--set", "nvm.read_cycles=slow",
    ]))
    .unwrap_err();
    assert!(e.contains("expected integer"), "got: {e}");
    // Missing '='.
    let e = spec_cli::spec_from_args(&parse(&[
        "sweep", "--set", "nvm.read_cycles",
    ]))
    .unwrap_err();
    assert!(e.contains("key=value"), "got: {e}");
}

#[test]
fn spec_file_loads_and_cli_overrides_layer_on_top() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("rainbow_spec_ok_{}.kv", std::process::id()));
    let spec = RunSpec::new("soplex", "rainbow")
        .with_scale(16)
        .with("rainbow.top_n", 25u64);
    std::fs::write(&path, spec_to_kv(&spec)).unwrap();
    let p = path.to_str().unwrap();
    let s = spec_cli::spec_from_args(&parse(&["run", "--spec", p])).unwrap();
    assert_eq!(s, spec);
    // Explicit CLI options beat the file; file fields otherwise stick.
    let s = spec_cli::spec_from_args(&parse(&[
        "run", "--spec", p, "--app", "mcf",
        "--set", "rainbow.top_n=50",
    ]))
    .unwrap();
    assert_eq!(s.workload, "mcf");
    assert_eq!(s.scale, 16);
    assert_eq!(s.overrides.get("rainbow.top_n"), Some(KnobValue::U64(50)));
    // The 0 sentinel resets the file's override back to the config
    // default instead of silently sticking with the file's value.
    let s = spec_cli::spec_from_args(&parse(&[
        "run", "--spec", p, "--top-n", "0",
    ]))
    .unwrap();
    assert_eq!(s.overrides.get("rainbow.top_n"), None);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn malformed_spec_files_are_rejected() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("rainbow_spec_bad_{}.kv", std::process::id()));
    for (body, why) in [
        ("workload=a\npolicy=flat\n", "missing specversion"),
        ("specversion=99\nworkload=a\npolicy=flat\n", "version"),
        ("specversion=1\nworkload=a\npolicy=flat\nset.bad.knob=1\n",
         "unknown config knob"),
        ("specversion=1\nworkload=a\npolicy=flat\ngarbage line\n",
         "key=value"),
        ("specversion=1\npolicy=flat\n", "workload"),
    ] {
        std::fs::write(&path, body).unwrap();
        let e = spec_cli::spec_from_args(
            &parse(&["run", "--spec", path.to_str().unwrap()]))
            .unwrap_err();
        assert!(e.contains("--spec"), "{why}: error should name the flag: {e}");
    }
    let _ = std::fs::remove_file(&path);
    // Nonexistent file.
    assert!(spec_cli::spec_from_args(
        &parse(&["run", "--spec", "/no/such/spec.kv"]))
        .is_err());
}

#[test]
fn profile_knobs_ride_the_set_surface() {
    let s = spec_cli::spec_from_args(&parse(&[
        "run", "--set", "nvm.profile=optane-dcpmm",
        "--set", "dram.profile=hbm-like",
    ]))
    .unwrap();
    assert_eq!(s.overrides.get("nvm.profile"),
               Some(KnobValue::Str("optane-dcpmm")));
    assert_eq!(s.overrides.get("dram.profile"),
               Some(KnobValue::Str("hbm-like")));
    // Unknown profile names fail before any fan-out, naming the catalog.
    let e = spec_cli::spec_from_args(&parse(&[
        "sweep", "--set", "nvm.profile=sdram-9000",
    ]))
    .unwrap_err();
    assert!(e.contains("unknown device profile"), "got: {e}");
    // A number is not a profile name.
    assert!(spec_cli::spec_from_args(&parse(&[
        "run", "--set", "nvm.profile=3",
    ]))
    .is_err());
}

#[test]
fn absurd_scale_rejected_with_a_clear_error() {
    // 4 GB DRAM / 4096 is far below the 16 MB page-table region; the
    // CLI must say so instead of letting Config::scaled panic later.
    let e = spec_cli::spec_from_args(&parse(&["run", "--scale", "4096"]))
        .unwrap_err();
    assert!(e.contains("too large"), "got: {e}");
}

#[test]
fn zero_interval_and_topn_keep_config_defaults() {
    // Historical CLI sentinel: 0 means "use the scaled config's value";
    // it must NOT become a (hang-inducing) interval_cycles=0 override.
    let s = spec_cli::spec_from_args(&parse(&[
        "run", "--interval", "0", "--top-n", "0",
    ]))
    .unwrap();
    assert!(s.overrides.is_empty());
    assert!(s.config().interval_cycles > 0);
}

#[test]
fn degenerate_knob_values_rejected_at_the_cli() {
    for bad in ["cpu.cores=0", "rainbow.interval_cycles=0", "dram.size=0",
                "rainbow.migration_threshold=nan"] {
        assert!(
            spec_cli::spec_from_args(&parse(&["sweep", "--set", bad]))
                .is_err(),
            "--set {bad} must be rejected before any worker spawns");
    }
}

#[test]
fn bad_scale_rejected_before_config_scaled_asserts() {
    // Config::scaled(0) divides by zero and non-powers-of-two assert;
    // both must take the CLI error path instead.
    for bad in ["0", "3"] {
        let e = spec_cli::spec_from_args(&parse(&["run", "--scale", bad]))
            .unwrap_err();
        assert!(e.contains("power of two"), "scale {bad}: got {e}");
    }
    assert!(spec_cli::spec_from_args(&parse(&["run", "--scale", "16"]))
        .is_ok());
}

#[test]
fn run_spec_names_validated_before_simulation() {
    // `run` takes spec_from_args straight to run_uncached; unknown
    // names must take the error path, not a panic.
    let e = spec_cli::spec_from_args(&parse(&["run", "--app", "notanapp"]))
        .unwrap_err();
    assert!(e.contains("unknown workload"), "got: {e}");
    let e = spec_cli::spec_from_args(
        &parse(&["run", "--policy", "notapolicy"])).unwrap_err();
    assert!(e.contains("unknown policy"), "got: {e}");
    // ...including names that arrive via a --spec file.
    let path = std::env::temp_dir()
        .join(format!("rainbow_spec_name_{}.kv", std::process::id()));
    std::fs::write(&path,
                   "specversion=1\nworkload=notanapp\npolicy=rainbow\n")
        .unwrap();
    let e = spec_cli::spec_from_args(
        &parse(&["run", "--spec", path.to_str().unwrap()])).unwrap_err();
    assert!(e.contains("unknown workload"), "got: {e}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn bad_workload_and_policy_names_rejected() {
    let e = spec_cli::sweep_workloads(
        &parse(&["sweep", "--apps", "mcf,notanapp"])).unwrap_err();
    assert!(e.contains("unknown workload"), "got: {e}");
    let e = spec_cli::sweep_policies(
        &parse(&["sweep", "--policies", "rainbow,notapolicy"])).unwrap_err();
    assert!(e.contains("unknown policy"), "got: {e}");
    // Empty lists are an error, not an empty sweep.
    assert!(spec_cli::sweep_workloads(
        &parse(&["sweep", "--apps", ","])).is_err());
    assert!(spec_cli::sweep_policies(
        &parse(&["sweep", "--policies", ","])).is_err());
    // Valid lists resolve (case-insensitive workloads, policy aliases).
    let ws = spec_cli::sweep_workloads(
        &parse(&["sweep", "--apps", "MCF,mix1"])).unwrap();
    assert_eq!(ws.len(), 2);
    let ps = spec_cli::sweep_policies(
        &parse(&["sweep", "--policies", "flat-static,rainbow"])).unwrap();
    assert_eq!(ps.len(), 2);
}

#[test]
fn spec_list_files_load_and_validate() {
    use rainbow::report::serde_kv::specs_to_kv;
    let dir = std::env::temp_dir();
    let path = dir.join(format!("rainbow_list_{}.kv", std::process::id()));
    let specs = vec![
        RunSpec::new("mcf", "rainbow").with("rainbow.top_n", 25u64),
        RunSpec::new("GUPS", "flat"),
    ];
    std::fs::write(&path, specs_to_kv(&specs)).unwrap();
    let back = spec_cli::load_spec_list(&path).unwrap();
    assert_eq!(back, specs);
    // A syntactically valid list with an unknown policy fails
    // validation, naming the file and block.
    let bad = vec![RunSpec::new("mcf", "notapolicy")];
    std::fs::write(&path, specs_to_kv(&bad)).unwrap();
    let e = spec_cli::load_spec_list(&path).unwrap_err();
    assert!(e.contains("unknown policy") && e.contains("block 1"),
            "got: {e}");
    let _ = std::fs::remove_file(&path);
    // Missing file errors cleanly.
    assert!(spec_cli::load_spec_list(
        std::path::Path::new("/no/such/list.kv")).is_err());
}

/// docs/MANUAL.md is the operator's manual for the whole experiment
/// surface; it must stay complete as the surface grows. Compiled in
/// with include_str! so editing the manual re-runs the guard.
#[test]
fn manual_covers_every_subcommand_knob_and_profile() {
    use rainbow::config::{knobs, profiles};
    let manual: &str =
        include_str!(concat!(env!("CARGO_MANIFEST_DIR"), "/docs/MANUAL.md"));
    for cmd in ["run", "sweep", "shard-worker", "queue-worker",
                "cache-server", "backends", "figure", "suite", "analyze",
                "storage", "perf", "stats", "trace-summary", "lint",
                "list"] {
        assert!(manual.contains(&format!("`{cmd}`")),
                "MANUAL.md must document the `{cmd}` subcommand");
    }
    for k in knobs::all() {
        assert!(manual.contains(&format!("`{}`", k.key)),
                "MANUAL.md must document the {} knob", k.key);
    }
    for p in profiles::all() {
        assert!(manual.contains(&format!("`{}`", p.name)),
                "MANUAL.md must document the {} device profile", p.name);
    }
    // The on-disk formats are versioned; the manual names each version
    // key so operators can recognize the files.
    for key in ["specversion", "speclistversion", "manifestversion"] {
        assert!(manual.contains(key),
                "MANUAL.md must describe the {key} format");
    }
    // The store surface: the --store argument forms (single server AND
    // replicated set), the wire protocol's integrity story, and the
    // durability/replication semantics must be documented for
    // operators.
    for needle in ["--store", "tcp://", "checksum", "--log",
                   "cachelogversion", "tcp://a,tcp://b", "read-repair",
                   "consistent-hash"] {
        assert!(manual.contains(needle),
                "MANUAL.md must describe the results-store {needle} \
                 surface");
    }
    // The job-queue surface: every queue opcode, the wire-record
    // version key, the lease-deadline knob, and the dynamic-dispatch
    // sweep flags must be documented for operators.
    for needle in ["LEASE", "COMPLETE", "REQUEUE", "QSTAT",
                   "queuewireversion", "--lease-ms", "--queue",
                   "--worker-id"] {
        assert!(manual.contains(needle),
                "MANUAL.md must describe the job-queue {needle} surface");
    }
    // The observability surface: the trace record catalog and its
    // version key, the emission flags, the fleet-stats opcode and wire
    // version, and the leveled log sink's env knob must be documented.
    for needle in ["--trace-out", "--csv-series", "traceversion",
                   "STATS", "statswireversion", "RAINBOW_LOG"] {
        assert!(manual.contains(needle),
                "MANUAL.md must describe the observability {needle} \
                 surface");
    }
    // The lint surface: every rule id, the suppression-marker syntax,
    // and the wire-format lock workflow must be documented.
    for r in rainbow::analysis::RULES {
        assert!(manual.contains(&format!("`{}`", r.id)),
                "MANUAL.md must document the {} lint rule", r.id);
    }
    for needle in ["rainbow-lint: allow(", "schemas.lock",
                   "--update-schemas", "--fix-allow", "--stale-allows",
                   "--list-rules"] {
        assert!(manual.contains(needle),
                "MANUAL.md must describe the lint {needle} surface");
    }
}

/// The CLI's `--store` argument accepts exactly a directory, a
/// `tcp://host:port`, or a replicated `tcp://a,tcp://b,...` endpoint
/// set; everything else is a clear error (the same `Store::parse` the
/// shard coordinator re-serializes onto child worker command lines —
/// including the multi-endpoint form, which rides `--store` as one
/// argv token).
#[test]
fn store_argument_forms() {
    use rainbow::report::{Store, StoreKind};
    let s = Store::parse("target/cli_store_test").unwrap();
    assert_eq!(s.kind(), StoreKind::Fs);
    let s = Store::parse("tcp://127.0.0.1:7700").unwrap();
    assert_eq!(s.kind(), StoreKind::Net);
    assert_eq!(s.addr(), "tcp://127.0.0.1:7700");
    let s = Store::parse("tcp://h1:7700,tcp://h2:7700,tcp://h3:7700")
        .unwrap();
    assert_eq!(s.kind(), StoreKind::Repl);
    assert_eq!(s.addr(), "tcp://h1:7700,tcp://h2:7700,tcp://h3:7700");
    assert_eq!(s.scheduler_hostport(), Some("h1:7700"));
    for bad in ["", "tcp://", "tcp://nohost", "tcp://h:x", "ftp://h:1",
                "tcp://h1:7700,h2:7700", "tcp://h1:7700,tcp://h1:7700",
                "tcp://h1:7700,"] {
        assert!(Store::parse(bad).is_err(), "{bad:?} must be rejected");
    }
}

#[test]
fn spec_kv_roundtrip_through_files() {
    let spec = RunSpec::new("mix2", "hscc4k")
        .with_seed(7)
        .with("mem.dram_ratio", 4u64)
        .with("rainbow.write_weight", 1.5);
    let text = spec_to_kv(&spec);
    let back = spec_from_kv(&text).unwrap();
    assert_eq!(spec, back);
    assert_eq!(spec.fingerprint(), back.fingerprint());
}
