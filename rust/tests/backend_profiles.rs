//! Acceptance contract of the device-profile API (DESIGN.md §8):
//! the paper profiles reproduce the baseline `Config::paper()`-derived
//! configuration and metrics byte-identically, profile names travel
//! through spec files and fingerprints, and specs differing only in a
//! backend never share a cache entry.

use rainbow::config::{profiles, Config, MemTech};
use rainbow::report::serde_kv::{metrics_to_kv, spec_from_kv, spec_to_kv};
use rainbow::report::sweep::{self, SweepConfig};
use rainbow::report::{run_uncached, RunSpec};

fn tiny(w: &str, p: &str) -> RunSpec {
    RunSpec::new(w, p)
        .with_scale(64)
        .with_instructions(40_000)
        .with_seed(7)
        .with("rainbow.interval_cycles", 100_000u64)
        .with("rainbow.top_n", 8u64)
}

fn with_paper_profiles(s: RunSpec) -> RunSpec {
    s.with("dram.profile", "ddr3-paper").with("nvm.profile", "pcm-paper")
}

#[test]
fn paper_profiles_reproduce_the_baseline_config_bit_exactly() {
    for scale in [1u64, 8, 64] {
        let base = RunSpec::new("mcf", "rainbow").with_scale(scale);
        let prof = with_paper_profiles(base.clone());
        assert_eq!(prof.config(), base.config(), "scale 1/{scale}");
    }
    // ...and the catalog entries themselves are Table IV verbatim.
    let paper = Config::paper();
    assert_eq!(profiles::by_name("ddr3-paper").unwrap().mem(), paper.dram);
    assert_eq!(profiles::by_name("pcm-paper").unwrap().mem(), paper.nvm);
}

#[test]
fn paper_profiles_reproduce_baseline_metrics_byte_identically() {
    let base = tiny("DICT", "rainbow");
    let a = run_uncached(&base);
    let b = run_uncached(&with_paper_profiles(base));
    assert_eq!(metrics_to_kv(&a), metrics_to_kv(&b));
}

#[test]
fn specs_differing_only_in_backend_get_distinct_cache_entries() {
    let pcm = tiny("DICT", "flat").with("nvm.profile", "pcm-paper");
    let opt = pcm.clone().with("nvm.profile", "optane-dcpmm");
    assert_ne!(pcm.fingerprint(), opt.fingerprint());

    // Run both through the disk-cached sweep: two distinct entries land,
    // and each replay hits its own.
    let dir = std::env::temp_dir().join(format!(
        "rainbow_backend_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = SweepConfig {
        workers: 2,
        disk_cache: true,
        store: Some(rainbow::report::Store::fs(dir.clone())),
    };
    let specs = vec![pcm.clone(), opt.clone()];
    let out = sweep::run(&specs, &cfg);
    assert_eq!(out.unique_runs, 2, "backends must not dedup together");
    for s in &specs {
        assert!(dir.join(format!("{}.kv", s.fingerprint())).is_file(),
                "missing cache entry for {}", s.fingerprint());
    }
    let again = sweep::run(&specs, &cfg);
    assert_eq!(metrics_to_kv(&out.metrics[0]), metrics_to_kv(&again.metrics[0]));
    assert_eq!(metrics_to_kv(&out.metrics[1]), metrics_to_kv(&again.metrics[1]));
    // The slow-tier swap must actually change the simulated outcome.
    assert_ne!(metrics_to_kv(&out.metrics[0]), metrics_to_kv(&out.metrics[1]));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn profile_names_survive_the_spec_file_round_trip() {
    let s = tiny("mcf", "rainbow")
        .with("nvm.profile", "stt-ram")
        .with("dram.profile", "hbm-like")
        .with("nvm.read_cycles", 9999u64);
    let kv = spec_to_kv(&s);
    let t = spec_from_kv(&kv).unwrap();
    assert_eq!(s, t);
    assert_eq!(s.fingerprint(), t.fingerprint());
    // Precedence survives the round trip too: profile expands first,
    // the explicit field override stays on top.
    let cfg = t.config();
    assert_eq!(cfg.nvm.tech, MemTech::SttRam);
    assert_eq!(cfg.dram.tech, MemTech::Hbm);
    assert_eq!(cfg.nvm.read_cycles, 9999);
}

#[test]
fn every_catalog_profile_simulates_in_either_slot() {
    // Smoke the whole catalog end-to-end: each profile must produce a
    // runnable config (no bank-decode or allocator panics) as the slow
    // tier, on a real (small) simulation.
    for p in profiles::all() {
        let spec = tiny("DICT", "rainbow")
            .with_instructions(20_000)
            .with_raw("nvm.profile", p.name);
        let m = run_uncached(&spec);
        assert!(m.cycles > 0, "{} produced no cycles", p.name);
    }
}
