//! PJRT integration: load the real AOT artifacts (built by
//! `make artifacts`) and verify the accelerated pipeline is bit-exact with
//! the native fallback — the contract `runtime::native` documents.
//!
//! These tests are skipped (not failed) when artifacts are absent so
//! `cargo test` works on a fresh checkout; `make test` always builds the
//! artifacts first.

use rainbow::config::Config;
use rainbow::rainbow::counters::TwoStageCounters;
use rainbow::rainbow::migration::UtilityParams;
use rainbow::runtime::{native, HotPageIdentifier, PjrtRuntime};
use rainbow::util::rng::Rng;

fn runtime() -> Option<PjrtRuntime> {
    let dir = PjrtRuntime::default_dir();
    match PjrtRuntime::load(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping PJRT test (artifacts not built?): {e:#}");
            None
        }
    }
}

const PARAMS: [f32; 8] = [62.0, 547.0, 43.0, 91.0, 4096.0, 4096.0, 64.0, 3.0];

#[test]
fn stage1_pjrt_matches_native_exactly() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(0xA0A0);
    for trial in 0..5 {
        let n = [256usize, 2048, 16384, 1000, 7][trial];
        let reads: Vec<i32> =
            (0..n).map(|_| rng.below(0x8000) as i32).collect();
        let writes: Vec<i32> =
            (0..n).map(|_| rng.below(0x8000) as i32).collect();
        let (score_p, idx_p) = rt.stage1(&reads, &writes, &PARAMS).unwrap();
        // Native over the same *padded* input for index agreement.
        let mut rp = reads.clone();
        rp.resize(rainbow::runtime::pjrt::N_SP, 0);
        let mut wp = writes.clone();
        wp.resize(rainbow::runtime::pjrt::N_SP, 0);
        let (score_n, idx_n) =
            native::stage1(&rp, &wp, &PARAMS, rainbow::runtime::pjrt::TOP_N);
        assert_eq!(&score_p[..n], &score_n[..n], "trial {trial} scores");
        assert_eq!(idx_p, idx_n, "trial {trial} top-k indices");
    }
}

#[test]
fn stage2_pjrt_matches_native_exactly() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(0xB1B1);
    for &slots in &[1usize, 16, 100, 128] {
        let n = slots * 512;
        let reads: Vec<i32> =
            (0..n).map(|_| rng.below(0x8000) as i32).collect();
        let writes: Vec<i32> =
            (0..n).map(|_| rng.below(0x8000) as i32).collect();
        let (b_p, h_p) = rt.stage2(&reads, &writes, &PARAMS).unwrap();
        let (b_n, h_n) = native::stage2(&reads, &writes, &PARAMS);
        assert_eq!(b_p, b_n, "slots={slots} benefit");
        assert_eq!(h_p, h_n, "slots={slots} hot mask");
    }
}

#[test]
fn identifier_backend_agreement_end_to_end() {
    let dir = PjrtRuntime::default_dir();
    let Ok(accel) = HotPageIdentifier::pjrt(&dir) else {
        eprintln!("skipping identifier agreement test (no artifacts)");
        return;
    };
    let native_id = HotPageIdentifier::native();
    assert_eq!(accel.backend_name(), "pjrt");

    let params = UtilityParams::from_config(&Config::paper());
    let mut counters = TwoStageCounters::new(2048, 64);
    let mut rng = Rng::new(0xC2C2);
    // Build a realistic counting state: skewed superpage traffic.
    for _ in 0..200_000 {
        let sp = (rng.below(64) * rng.below(32) / 31) as u32; // skewed
        counters.record(sp, rng.below(512) as u16, rng.chance(0.3));
    }
    let top_a = accel.select_top(&counters, &params);
    let top_n = native_id.select_top(&counters, &params);
    assert_eq!(top_a, top_n, "stage-1 selection must agree");

    counters.rotate(&top_a);
    for _ in 0..100_000 {
        let sp = top_a[rng.below(top_a.len() as u64) as usize];
        counters.record(sp, rng.below(64) as u16, rng.chance(0.5));
    }
    let v_a = accel.classify(&counters, &params);
    let v_n = native_id.classify(&counters, &params);
    assert_eq!(v_a.len(), v_n.len());
    for (a, n) in v_a.iter().zip(v_n.iter()) {
        assert_eq!(a.sp, n.sp);
        assert_eq!(a.hot_pages, n.hot_pages);
    }
}

#[test]
fn rainbow_policy_runs_with_accel_backend() {
    if runtime().is_none() {
        return;
    }
    // Full simulation with the PJRT identifier on a small workload.
    let spec = rainbow::report::RunSpec::new("DICT", "rainbow")
        .with_scale(64)
        .with_instructions(80_000)
        .with("rainbow.interval_cycles", 100_000u64)
        .with("rainbow.top_n", 16u64);
    let accel = rainbow::report::run_uncached(&spec.clone().with_accel(true));
    let native = rainbow::report::run_uncached(&spec);
    // Identical identification decisions => identical simulations.
    assert_eq!(accel.cycles, native.cycles,
               "accel and native runs must be cycle-identical");
    assert_eq!(accel.migrations, native.migrations);
}
