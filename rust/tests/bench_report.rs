//! The committed `BENCH_<n>.json` perf-trajectory files are part of the
//! repo's contract: every one must parse and validate against the
//! `rainbow-bench-v1` schema (the same validator `rainbow perf
//! --validate` and the CI bench-smoke job run), and the newest report
//! must cover every hot-path stage the harness measures today. A schema
//! or stage-list change must update the committed reports (or bump the
//! schema) in the same PR — this test is what fails otherwise.

use rainbow::perf::{self, REQUIRED_STAGES};
use rainbow::util::json::{self, Json};

fn repo_root() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// All committed BENCH_*.json files, (numeric suffix, parsed doc).
fn committed_reports() -> Vec<(u64, Json)> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(repo_root()).unwrap() {
        let name = entry.unwrap().file_name();
        let name = name.to_string_lossy().into_owned();
        let Some(num) = name
            .strip_prefix("BENCH_")
            .and_then(|rest| rest.strip_suffix(".json"))
        else {
            continue;
        };
        let Ok(n) = num.parse::<u64>() else { continue };
        let text = std::fs::read_to_string(repo_root().join(&name))
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        let doc = json::parse(&text)
            .unwrap_or_else(|e| panic!("{name} must parse: {e}"));
        out.push((n, doc));
    }
    out.sort_by_key(|(n, _)| *n);
    out
}

#[test]
fn every_committed_bench_report_validates() {
    let reports = committed_reports();
    assert!(!reports.is_empty(),
            "the perf campaign must have at least one committed \
             BENCH_<n>.json at the repo root");
    for (n, doc) in &reports {
        perf::validate(doc)
            .unwrap_or_else(|e| panic!("BENCH_{n}.json invalid: {e}"));
    }
}

#[test]
fn newest_report_covers_every_current_stage() {
    let reports = committed_reports();
    let (n, doc) = reports.last().expect("at least BENCH_6.json");
    let names: Vec<&str> = doc
        .get("benches")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|b| b.get("name").and_then(Json::as_str).unwrap())
        .collect();
    for stage in REQUIRED_STAGES {
        assert!(names.contains(&stage),
                "BENCH_{n}.json must cover stage {stage:?} (regenerate \
                 with `cargo run --release -- perf --out BENCH_{n}.json`)");
    }
    for pol in rainbow::policies::all_names() {
        let want = format!("policy.{pol}.access");
        assert!(names.iter().any(|&x| x == want),
                "BENCH_{n}.json must cover {want:?}");
    }
}

#[test]
fn reports_share_one_schema_and_fingerprinted_configs() {
    for (n, doc) in committed_reports() {
        assert_eq!(doc.get("schema").and_then(Json::as_str),
                   Some(perf::SCHEMA), "BENCH_{n}.json schema");
        let fp = doc
            .get("config")
            .and_then(|c| c.get("fingerprint"))
            .and_then(Json::as_str)
            .unwrap_or_else(|| panic!("BENCH_{n}.json fingerprint"));
        assert!(fp.starts_with("rainbow-perf "),
                "BENCH_{n}.json fingerprint {fp:?} must be the \
                 self-describing rainbow-perf form");
    }
}
