//! Cross-policy integration tests: the paper's qualitative claims, each
//! checked on real (small) simulations. These encode the *shape* contract
//! of the reproduction (DESIGN.md §4).

use rainbow::report::{run_uncached, RunSpec};

fn spec(workload: &str, policy: &str) -> RunSpec {
    RunSpec::new(workload, policy)
        .with_scale(32)
        .with_instructions(600_000)
        .with_seed(42)
}

#[test]
fn superpages_slash_mpki_by_orders_of_magnitude() {
    // Fig. 7: flat 4 KB MPKI vs Rainbow MPKI differs by >= 100x.
    let flat = run_uncached(&spec("mcf", "flat"));
    let rb = run_uncached(&spec("mcf", "rainbow"));
    assert!(flat.mpki() > 1.0, "flat MPKI {:.3} too low", flat.mpki());
    assert!(rb.mpki() < flat.mpki() / 100.0,
            "rainbow {:.4} vs flat {:.2}", rb.mpki(), flat.mpki());
}

#[test]
fn tlb_miss_cycles_shrink_with_superpages() {
    // Fig. 8: 4 KB systems spend a large fraction on TLB misses;
    // superpage systems spend a tiny one.
    let flat = run_uncached(&spec("soplex", "flat"));
    let rb = run_uncached(&spec("soplex", "rainbow"));
    assert!(flat.tlb_miss_cycle_frac() > 0.01);
    assert!(rb.tlb_miss_cycle_frac() < flat.tlb_miss_cycle_frac() / 5.0);
}

#[test]
fn dram_only_is_the_upper_bound() {
    // Fig. 10: DRAM-only beats every hybrid policy.
    for w in ["DICT", "GUPS"] {
        let dram = run_uncached(&spec(w, "dram")).ipc();
        for p in ["flat", "hscc4k", "hscc2m", "rainbow"] {
            let ipc = run_uncached(&spec(w, p)).ipc();
            assert!(dram > ipc, "{w}: dram {dram:.4} <= {p} {ipc:.4}");
        }
    }
}

#[test]
fn rainbow_beats_flat_static() {
    // Headline direction (Fig. 10): Rainbow > Flat-static on hot-heavy
    // workloads. Needs the standard 1/8-scale regime and enough
    // instructions to amortize migration warm-up.
    for w in ["DICT", "soplex"] {
        let sf = RunSpec::new(w, "flat")
            .with_scale(8)
            .with_instructions(1_500_000)
            .with_seed(42);
        let sr = sf.clone().with_policy("rainbow");
        let flat = run_uncached(&sf).ipc();
        let rb = run_uncached(&sr).ipc();
        assert!(rb > flat, "{w}: rainbow {rb:.4} <= flat {flat:.4}");
    }
}

#[test]
fn superpage_migration_traffic_exceeds_rainbow_when_it_migrates() {
    // Fig. 11: per migrated unit, HSCC-2MB moves 512x more than needed;
    // Rainbow's traffic per migration is always 4 KB.
    let rb = run_uncached(&spec("DICT", "rainbow"));
    let h2 = run_uncached(&spec("DICT", "hscc2m"));
    if h2.migrations > 0 && rb.migrations > 0 {
        let per_mig_2m = h2.migrated_bytes / h2.migrations;
        let per_mig_rb = rb.migrated_bytes / rb.migrations;
        assert_eq!(per_mig_2m, 512 * per_mig_rb);
    }
    // And Rainbow must actually migrate on a hot-heavy app.
    assert!(rb.migrations > 0);
}

#[test]
fn rainbow_never_shoots_down_on_migrate_in() {
    // §III-F: NVM->DRAM migration requires no TLB shootdown; shootdowns
    // only come from DRAM evictions. With DRAM far larger than the
    // footprint at this scale, there must be zero.
    let rb = run_uncached(&spec("streamcluster", "rainbow"));
    assert!(rb.migrations > 0);
    assert_eq!(rb.shootdowns, 0);
    // HSCC-4KB by contrast shoots down once per migration.
    let h4 = run_uncached(&spec("streamcluster", "hscc4k"));
    assert!(h4.shootdowns >= h4.migrations.min(1));
}

#[test]
fn superpage_tlb_hit_rate_is_high() {
    // §III-E: the mechanism relies on R_hit being high (>99% in the
    // paper); check Rainbow sustains it on a large-footprint app.
    let rb = run_uncached(&spec("Graph500", "rainbow"));
    assert!(rb.sp_hit_rate > 0.90, "R_hit = {:.4}", rb.sp_hit_rate);
}

#[test]
fn energy_hybrids_beat_dram_only_on_background() {
    // Fig. 12 direction: Rainbow consumes less energy than Flat-static
    // (hot pages served by DRAM instead of expensive PCM writes).
    let flat = run_uncached(&spec("DICT", "flat"));
    let rb = run_uncached(&spec("DICT", "rainbow"));
    // At 1/32 scale with short runs the background term is small; the
    // robust direction is "not meaningfully worse" (full-scale runs in
    // EXPERIMENTS.md show the paper's 45% advantage regime).
    assert!(rb.energy_pj < flat.energy_pj * 1.15,
            "rainbow {:.2e} vs flat {:.2e}", rb.energy_pj, flat.energy_pj);
}

#[test]
fn deterministic_replay_across_policies() {
    // The same spec twice yields identical metrics (whole-suite guarantee).
    let a = run_uncached(&spec("mix2", "rainbow"));
    let b = run_uncached(&spec("mix2", "rainbow"));
    assert_eq!(a.cycles, b.cycles);
    assert_eq!(a.migrations, b.migrations);
    assert_eq!(a.tlb_miss_2m, b.tlb_miss_2m);
}

#[test]
fn mixes_run_all_policies() {
    for p in ["flat", "hscc4k", "hscc2m", "rainbow", "dram"] {
        let m = run_uncached(&spec("mix1", p));
        assert_eq!(m.instructions, 600_000, "policy {p}");
        assert!(m.ipc() > 0.0);
    }
}
