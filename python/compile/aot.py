"""AOT lowering: JAX pipeline -> HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``lowered.compile()`` / proto ``.serialize()``) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published
``xla`` 0.1.6 crate links) rejects; the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Emits:  hotpage_stage1.hlo.txt, hotpage_stage2.hlo.txt, manifest.txt
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(fn, example_args, name, out_dir):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {name}: {len(text)} chars -> {path}")
    return path, text


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    # Back-compat single-file flag used by older Makefile rules.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    manifest = []
    for spec_fn, fn in ((model.stage1_spec, model.stage1),
                        (model.stage2_spec, model.stage2)):
        example_args, name = spec_fn()
        path, text = lower_one(fn, example_args, name, out_dir)
        manifest.append((name, os.path.basename(path), len(text)))

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write(f"n_sp={model.N_SP} top_n={model.TOP_N} "
                f"sp_pages={model.SP_PAGES}\n")
        for name, base, size in manifest:
            f.write(f"{name} {base} {size}\n")
    print(f"manifest -> {out_dir}/manifest.txt")


if __name__ == "__main__":
    main()
