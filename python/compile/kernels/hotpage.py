"""L1 Pallas kernels for the Rainbow interval analytics.

Two kernels, both VPU-elementwise (no MXU), tiled so each block fits
comfortably in VMEM on a real TPU (see DESIGN.md §7):

* ``score_kernel``   — stage-1 weighted superpage scoring over the
  (N_SP,) counter arrays. Block = 2048 lanes = 8 KiB/operand in f32.
* ``benefit_kernel`` — stage-2 fused Eq.-1 benefit + hot classification
  over the (TOP_N, 512) small-page counter tiles. Block = (16, 512)
  = 32 KiB/operand in f32; three operands in, two out -> ~160 KiB live,
  double-bufferable within 16 MiB VMEM.

``interpret=True`` is mandatory in this image: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO that
both jax-CPU and the rust PJRT client run (and that AOT serializes).

Scalar parameters are broadcast as small (1, 8) blocks replicated to every
grid step rather than SMEM scalars, which keeps the lowering portable
across interpret/Mosaic.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

SCORE_BLOCK = 2048          # lanes per stage-1 grid step
BENEFIT_BLOCK_ROWS = 16     # superpages per stage-2 grid step


def _score_kernel(params_ref, reads_ref, writes_ref, score_ref):
    w = params_ref[0, ref.P_WWEIGHT]
    score_ref[...] = (
        reads_ref[...].astype(jnp.float32)
        + w * writes_ref[...].astype(jnp.float32)
    )


def superpage_score_pallas(sp_reads, sp_writes, params, block=SCORE_BLOCK):
    """Pallas version of ``ref.superpage_score`` (f32[N])."""
    n = sp_reads.shape[0]
    assert n % block == 0, f"N_SP={n} must be a multiple of block={block}"
    grid = (n // block,)
    return pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(params.reshape(1, 8), sp_reads, sp_writes)


def _benefit_kernel(params_ref, reads_ref, writes_ref, benefit_ref, hot_ref):
    p = params_ref[0]
    dr = p[ref.P_TNR] - p[ref.P_TDR]
    dw = p[ref.P_TNW] - p[ref.P_TDW]
    r = reads_ref[...]
    w = writes_ref[...]
    benefit = (
        dr * r.astype(jnp.float32)
        + dw * w.astype(jnp.float32)
        - p[ref.P_TMIG]
    )
    touched = (r + w) > 0
    benefit_ref[...] = benefit
    hot_ref[...] = ((benefit > p[ref.P_THRESH]) & touched).astype(jnp.int32)


def benefit_classify_pallas(
    pg_reads, pg_writes, params, block_rows=BENEFIT_BLOCK_ROWS
):
    """Pallas version of stage 2: (benefit f32[N,512], hot i32[N,512])."""
    n, cols = pg_reads.shape
    assert cols == ref.SP_PAGES, f"expected {ref.SP_PAGES} pages/superpage"
    assert n % block_rows == 0, f"TOP_N={n} not multiple of {block_rows}"
    grid = (n // block_rows,)
    return pl.pallas_call(
        _benefit_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, cols), jnp.float32),
            jax.ShapeDtypeStruct((n, cols), jnp.int32),
        ],
        interpret=True,
    )(params.reshape(1, 8), pg_reads, pg_writes)
