"""Pure-jnp reference oracle for the Rainbow interval-analytics pipeline.

This is the correctness ground truth for the Pallas kernels in
``hotpage.py`` and for the Rust native fallback
(``rust/src/runtime/native.rs``), which is written to be bit-exact with
the math here (f32 arithmetic, stable lowest-index tie-break in top-k).

The pipeline implements the paper's two-stage hot-page identification
(Fig. 3/4) and the utility migration model (Eq. 1):

  stage 1:  score(sp)   = reads(sp) + write_weight * writes(sp)
            top-N superpages by score (stable: ties -> lower index)
  stage 2:  benefit(pg) = (t_nr - t_dr) * C_r + (t_nw - t_dw) * C_w - T_mig
            hot(pg)     = benefit > threshold  (and touched at all)

Parameter vector layout (f32[8]):
  [0] t_nr   NVM read latency (cycles)
  [1] t_nw   NVM write latency
  [2] t_dr   DRAM read latency
  [3] t_dw   DRAM write latency
  [4] T_mig  cycles per 4 KB page migration
  [5] T_wb   cycles per dirty-page writeback (Eq. 2 path, used by caller)
  [6] threshold  minimum benefit (cycles) to classify hot
  [7] write_weight  weighting of writes in superpage scoring
"""

import jax.numpy as jnp
from jax import lax

# Fixed AOT shapes (see DESIGN.md §5). The simulator pads/truncates to these.
N_SP = 16384      # superpages tracked by the stage-1 counter array
TOP_N = 128       # superpages monitored at 4 KB granularity in stage 2
SP_PAGES = 512    # 4 KB pages per 2 MB superpage

P_TNR, P_TNW, P_TDR, P_TDW, P_TMIG, P_TWB, P_THRESH, P_WWEIGHT = range(8)


def superpage_score(sp_reads, sp_writes, params):
    """Stage-1 scoring: weighted access count per superpage (f32)."""
    w = params[P_WWEIGHT]
    return sp_reads.astype(jnp.float32) + w * sp_writes.astype(jnp.float32)


def top_n_superpages(score, n=TOP_N):
    """Indices of the n highest-scoring superpages, stable by lower index.

    ``lax.top_k`` already breaks ties by lowest index; we rely on that and
    mirror it in the Rust fallback.
    """
    _, idx = lax.top_k(score, n)
    return idx.astype(jnp.int32)


def page_benefit(pg_reads, pg_writes, params):
    """Eq. 1 migration benefit per 4 KB page (f32, cycles)."""
    dr = params[P_TNR] - params[P_TDR]
    dw = params[P_TNW] - params[P_TDW]
    return (
        dr * pg_reads.astype(jnp.float32)
        + dw * pg_writes.astype(jnp.float32)
        - params[P_TMIG]
    )


def classify_hot(benefit, pg_reads, pg_writes, params):
    """Hot mask: benefit above threshold and the page was touched."""
    touched = (pg_reads + pg_writes) > 0
    return ((benefit > params[P_THRESH]) & touched).astype(jnp.int32)


def stage1_ref(sp_reads, sp_writes, params):
    """Full stage 1: (score f32[N], topn i32[TOP_N])."""
    score = superpage_score(sp_reads, sp_writes, params)
    return score, top_n_superpages(score, TOP_N)


def stage2_ref(pg_reads, pg_writes, params):
    """Full stage 2: (benefit f32[N,512], hot i32[N,512])."""
    benefit = page_benefit(pg_reads, pg_writes, params)
    return benefit, classify_hot(benefit, pg_reads, pg_writes, params)
