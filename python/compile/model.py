"""L2 JAX model: the Rainbow per-interval hot-page analytics pipeline.

Composes the L1 Pallas kernels (``kernels.hotpage``) with the top-k
selection into the two artifacts the Rust coordinator executes every
sampling interval:

* ``stage1(sp_reads i32[N_SP], sp_writes i32[N_SP], params f32[8])
      -> (score f32[N_SP], topn i32[TOP_N])``
  Weighted superpage scoring (Pallas) + lax.top_k selection. The Rust
  side then gathers the 4 KB counters of the selected superpages.

* ``stage2(pg_reads i32[TOP_N,512], pg_writes i32[TOP_N,512], params)
      -> (benefit f32[TOP_N,512], hot i32[TOP_N,512])``
  Fused Eq.-1 benefit + threshold classification (Pallas).

Both are pure functions of their inputs with fixed shapes, so they lower
once (``aot.py``) and never require Python at simulation time.
"""

import jax.numpy as jnp

from .kernels import hotpage, ref

N_SP = ref.N_SP
TOP_N = ref.TOP_N
SP_PAGES = ref.SP_PAGES


def stage1(sp_reads, sp_writes, params):
    """Superpage scoring + top-N selection. Returns (score, topn_idx).

    Top-N uses a stable argsort on the negated score rather than
    ``lax.top_k``: semantics are identical (descending value, ties to the
    lowest index — what the Rust native fallback mirrors), but the sort
    lowering parses on xla_extension 0.5.1, whose HLO parser predates the
    TopK op's ``largest`` attribute.
    """
    score = hotpage.superpage_score_pallas(sp_reads, sp_writes, params)
    idx = jnp.argsort(-score, stable=True)[:TOP_N]
    return score, idx.astype(jnp.int32)


def stage2(pg_reads, pg_writes, params):
    """Per-page migration benefit + hot classification."""
    benefit, hot = hotpage.benefit_classify_pallas(pg_reads, pg_writes, params)
    return benefit, hot


def stage1_spec():
    """(example_args, name) for AOT lowering of stage1."""
    import jax

    return (
        (
            jax.ShapeDtypeStruct((N_SP,), jnp.int32),
            jax.ShapeDtypeStruct((N_SP,), jnp.int32),
            jax.ShapeDtypeStruct((8,), jnp.float32),
        ),
        "hotpage_stage1",
    )


def stage2_spec():
    """(example_args, name) for AOT lowering of stage2."""
    import jax

    return (
        (
            jax.ShapeDtypeStruct((TOP_N, SP_PAGES), jnp.int32),
            jax.ShapeDtypeStruct((TOP_N, SP_PAGES), jnp.int32),
            jax.ShapeDtypeStruct((8,), jnp.float32),
        ),
        "hotpage_stage2",
    )
