"""L2 pipeline tests: stage composition, top-k semantics, AOT lowering."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref

PARAMS = jnp.array([62.0, 547.0, 43.0, 91.0, 4096.0, 4096.0, 64.0, 3.0],
                   dtype=jnp.float32)


def test_stage1_selects_known_hot_superpages():
    rng = np.random.default_rng(42)
    r = rng.integers(0, 4, size=model.N_SP).astype(np.int32)
    w = np.zeros(model.N_SP, np.int32)
    hot = rng.choice(model.N_SP, size=model.TOP_N, replace=False)
    r[hot] = 10_000
    score, idx = model.stage1(jnp.array(r), jnp.array(w), PARAMS)
    assert score.shape == (model.N_SP,)
    assert idx.shape == (model.TOP_N,)
    assert set(np.asarray(idx).tolist()) == set(hot.tolist())


def test_stage1_topk_tie_break_lowest_index():
    """All-equal scores -> top_k must return 0..TOP_N-1 (the Rust native
    fallback mirrors exactly this)."""
    ones = jnp.ones(model.N_SP, jnp.int32)
    _, idx = model.stage1(ones, ones, PARAMS)
    np.testing.assert_array_equal(np.asarray(idx),
                                  np.arange(model.TOP_N, dtype=np.int32))


def test_stage1_topk_descending_scores():
    rng = np.random.default_rng(7)
    r = rng.integers(0, 1000, size=model.N_SP).astype(np.int32)
    w = rng.integers(0, 1000, size=model.N_SP).astype(np.int32)
    score, idx = model.stage1(jnp.array(r), jnp.array(w), PARAMS)
    s = np.asarray(score)[np.asarray(idx)]
    assert np.all(np.diff(s) <= 0), "top-k scores must be non-increasing"
    # and nothing outside the selection beats the minimum selected score
    mask = np.ones(model.N_SP, bool)
    mask[np.asarray(idx)] = False
    assert np.all(np.asarray(score)[mask] <= s[-1])


def test_stage2_threshold_monotonicity():
    """Raising the threshold can only shrink the hot set (paper §IV-F)."""
    rng = np.random.default_rng(3)
    r = jnp.array(rng.integers(0, 200, size=(model.TOP_N, model.SP_PAGES)),
                  jnp.int32)
    w = jnp.array(rng.integers(0, 200, size=(model.TOP_N, model.SP_PAGES)),
                  jnp.int32)
    hots = []
    for t in (0.0, 1e3, 1e4, 1e5):
        p = np.asarray(PARAMS).copy()
        p[ref.P_THRESH] = t
        _, hot = model.stage2(r, w, jnp.array(p))
        hots.append(int(np.asarray(hot).sum()))
    assert hots == sorted(hots, reverse=True)


def test_full_pipeline_against_ref():
    rng = np.random.default_rng(11)
    spr = jnp.array(rng.integers(0, 0x7FFF, model.N_SP), jnp.int32)
    spw = jnp.array(rng.integers(0, 0x7FFF, model.N_SP), jnp.int32)
    s_got, i_got = model.stage1(spr, spw, PARAMS)
    s_ref, i_ref = ref.stage1_ref(spr, spw, PARAMS)
    np.testing.assert_array_equal(np.asarray(s_got), np.asarray(s_ref))
    np.testing.assert_array_equal(np.asarray(i_got), np.asarray(i_ref))

    pgr = jnp.array(rng.integers(0, 0x7FFF, (model.TOP_N, model.SP_PAGES)),
                    jnp.int32)
    pgw = jnp.array(rng.integers(0, 0x7FFF, (model.TOP_N, model.SP_PAGES)),
                    jnp.int32)
    b_got, h_got = model.stage2(pgr, pgw, PARAMS)
    b_ref, h_ref = ref.stage2_ref(pgr, pgw, PARAMS)
    np.testing.assert_array_equal(np.asarray(b_got), np.asarray(b_ref))
    np.testing.assert_array_equal(np.asarray(h_got), np.asarray(h_ref))


def test_aot_lowering_emits_parseable_hlo(tmp_path):
    """Both artifacts lower to HLO text containing an ENTRY computation."""
    from compile import aot

    for spec_fn, fn in ((model.stage1_spec, model.stage1),
                        (model.stage2_spec, model.stage2)):
        example_args, name = spec_fn()
        path, text = aot.lower_one(fn, example_args, name, str(tmp_path))
        assert "ENTRY" in text
        assert "HloModule" in text
        assert (tmp_path / f"{name}.hlo.txt").exists()


def test_stage1_jit_roundtrip_stablehlo():
    """The lowering path used by aot.py must preserve numerics vs eager."""
    rng = np.random.default_rng(5)
    spr = jnp.array(rng.integers(0, 100, model.N_SP), jnp.int32)
    spw = jnp.array(rng.integers(0, 100, model.N_SP), jnp.int32)
    eager = model.stage1(spr, spw, PARAMS)
    jitted = jax.jit(model.stage1)(spr, spw, PARAMS)
    np.testing.assert_array_equal(np.asarray(eager[0]), np.asarray(jitted[0]))
    np.testing.assert_array_equal(np.asarray(eager[1]), np.asarray(jitted[1]))
