"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes/dtypes/values; fixed seeds keep runs deterministic.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hotpage, ref

RNG = np.random.default_rng(0x5EED)

PARAMS = np.array(
    # tnr   tnw   tdr   tdw   tmig   twb   thresh  wweight
    [62.0, 547.0, 43.0, 91.0, 4096.0, 4096.0, 64.0, 3.0],
    dtype=np.float32,
)


def rand_counts(shape, hi=0x7FFF):
    return RNG.integers(0, hi, size=shape, dtype=np.int32)


# ---------------------------------------------------------------- stage 1

def test_score_matches_ref_full_shape():
    r = rand_counts((ref.N_SP,))
    w = rand_counts((ref.N_SP,))
    got = hotpage.superpage_score_pallas(jnp.array(r), jnp.array(w),
                                         jnp.array(PARAMS))
    want = ref.superpage_score(jnp.array(r), jnp.array(w), jnp.array(PARAMS))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_score_zero_counts_is_zero():
    z = jnp.zeros((ref.N_SP,), jnp.int32)
    got = hotpage.superpage_score_pallas(z, z, jnp.array(PARAMS))
    assert not np.any(np.asarray(got))


def test_score_write_weighting():
    """A write must count write_weight times a read (paper §III-B)."""
    r = np.zeros(ref.N_SP, np.int32)
    w = np.zeros(ref.N_SP, np.int32)
    r[7] = 1
    w[9] = 1
    got = np.asarray(
        hotpage.superpage_score_pallas(jnp.array(r), jnp.array(w),
                                       jnp.array(PARAMS)))
    assert got[7] == 1.0
    assert got[9] == PARAMS[ref.P_WWEIGHT]


@settings(max_examples=25, deadline=None)
@given(
    block_pow=st.integers(min_value=7, max_value=11),
    nblocks=st.integers(min_value=1, max_value=4),
    hi=st.integers(min_value=1, max_value=0x8000),
    wweight=st.floats(min_value=0.0, max_value=16.0, allow_nan=False),
)
def test_score_hypothesis_shapes(block_pow, nblocks, hi, wweight):
    """Sweep block sizes and counter magnitudes (incl. 15-bit overflow cap)."""
    block = 1 << block_pow
    n = block * nblocks
    rng = np.random.default_rng(block + nblocks + hi)
    r = rng.integers(0, hi, size=n, dtype=np.int32)
    w = rng.integers(0, hi, size=n, dtype=np.int32)
    p = PARAMS.copy()
    p[ref.P_WWEIGHT] = np.float32(wweight)
    got = hotpage.superpage_score_pallas(jnp.array(r), jnp.array(w),
                                         jnp.array(p), block=block)
    want = ref.superpage_score(jnp.array(r), jnp.array(w), jnp.array(p))
    # XLA may fuse the multiply-add into an FMA on one path only, so the
    # pallas and jnp results can differ by 1 ULP for non-representable
    # weights; exact-weight tests above stay bit-exact.
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ---------------------------------------------------------------- stage 2

def test_benefit_matches_ref_full_shape():
    r = rand_counts((ref.TOP_N, ref.SP_PAGES))
    w = rand_counts((ref.TOP_N, ref.SP_PAGES))
    gb, gh = hotpage.benefit_classify_pallas(jnp.array(r), jnp.array(w),
                                             jnp.array(PARAMS))
    wb, wh = ref.stage2_ref(jnp.array(r), jnp.array(w), jnp.array(PARAMS))
    np.testing.assert_array_equal(np.asarray(gb), np.asarray(wb))
    np.testing.assert_array_equal(np.asarray(gh), np.asarray(wh))


def test_untouched_page_never_hot():
    """benefit = -T_mig < 0 for untouched pages, and the touched-guard holds
    even with a negative threshold."""
    z = jnp.zeros((ref.TOP_N, ref.SP_PAGES), jnp.int32)
    p = PARAMS.copy()
    p[ref.P_THRESH] = -1e9
    benefit, hot = hotpage.benefit_classify_pallas(z, z, jnp.array(p))
    assert float(np.max(np.asarray(benefit))) == -PARAMS[ref.P_TMIG]
    assert not np.any(np.asarray(hot))


def test_write_heavy_page_hotter_than_read_heavy():
    """NVM writes are ~9x slower than DRAM writes vs ~1.4x for reads, so a
    write-heavy page must show a larger benefit (paper Observation/Eq. 1)."""
    r = np.zeros((ref.TOP_N, ref.SP_PAGES), np.int32)
    w = np.zeros((ref.TOP_N, ref.SP_PAGES), np.int32)
    r[0, 0] = 100  # read-heavy page
    w[0, 1] = 100  # write-heavy page
    benefit, _ = hotpage.benefit_classify_pallas(
        jnp.array(r), jnp.array(w), jnp.array(PARAMS))
    b = np.asarray(benefit)
    assert b[0, 1] > b[0, 0]


@settings(max_examples=20, deadline=None)
@given(
    rows_pow=st.integers(min_value=0, max_value=3),
    nblocks=st.integers(min_value=1, max_value=4),
    thresh=st.floats(min_value=-1e4, max_value=1e4, allow_nan=False),
)
def test_benefit_hypothesis_shapes(rows_pow, nblocks, thresh):
    block_rows = 1 << rows_pow
    n = block_rows * nblocks
    rng = np.random.default_rng(rows_pow * 131 + nblocks)
    r = rng.integers(0, 0x7FFF, size=(n, ref.SP_PAGES), dtype=np.int32)
    w = rng.integers(0, 0x7FFF, size=(n, ref.SP_PAGES), dtype=np.int32)
    p = PARAMS.copy()
    p[ref.P_THRESH] = np.float32(thresh)
    gb, gh = hotpage.benefit_classify_pallas(
        jnp.array(r), jnp.array(w), jnp.array(p), block_rows=block_rows)
    wb, wh = ref.stage2_ref(jnp.array(r), jnp.array(w), jnp.array(p))
    np.testing.assert_array_equal(np.asarray(gb), np.asarray(wb))
    np.testing.assert_array_equal(np.asarray(gh), np.asarray(wh))


# ------------------------------------------------------------- invariants

def test_hot_mask_is_binary_and_implies_positive_net_benefit():
    r = rand_counts((ref.TOP_N, ref.SP_PAGES), hi=128)
    w = rand_counts((ref.TOP_N, ref.SP_PAGES), hi=128)
    benefit, hot = hotpage.benefit_classify_pallas(
        jnp.array(r), jnp.array(w), jnp.array(PARAMS))
    b, h = np.asarray(benefit), np.asarray(hot)
    assert set(np.unique(h)) <= {0, 1}
    assert np.all(b[h == 1] > PARAMS[ref.P_THRESH])
    # complement: cold pages are below-threshold OR untouched
    cold = h == 0
    below = b <= PARAMS[ref.P_THRESH]
    untouched = (r + w) == 0
    assert np.all(below[cold] | untouched[cold])
